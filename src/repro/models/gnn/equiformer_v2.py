"""EquiformerV2 (Liao et al., arXiv:2306.12059): equivariant graph attention
with eSCN-style SO(2) convolutions.

Per edge: rotate source irreps (l ≤ l_max) into the edge-aligned frame with
real-basis Wigner matrices, apply the m-sparse SO(2) linear map (m ≤ m_max —
the eSCN O(L⁶)→O(L³) reduction), gate by radial features, weight by
multi-head attention from invariant (m=0) channels, rotate back, scatter-sum
to destinations. Equivariant LayerNorm + gated nonlinearity + per-l FFN.

Features: (N, (l_max+1)², C). Equivariance is property-tested end-to-end.

Large graphs (ogb_products: 61.9M edges × 49 irreps × 128 ch ≈ 1.5 TB of
per-edge state) are processed with `edge_chunks > 1`: a first chunked pass
computes attention logits (per-edge scalars only), softmax normalizes
globally, a second chunked+rematerialized pass computes and scatters the
messages — two sweeps over the edge partitions, exactly the PSW discipline.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List

import jax
import jax.numpy as jnp

from ...graph.segment_ops import edge_softmax, scatter_sum
from ...sharding import constrain
from .common import init_mlp, mlp_apply
from .wigner import blockdiag_apply, irreps_dim, rotation_to_z, wigner_rotations


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_species: int = 32       # atom-type vocabulary
    n_rbf: int = 32
    cutoff: float = 5.0
    d_out: int = 1            # invariant output width
    edge_chunks: int = 1      # >1: two-pass chunked edge processing
    gather_mode: str = "take"  # take | psw_ring (DESIGN.md §2 ring windows)
    remat_layers: bool = False  # checkpoint whole layers (huge graphs)


def _l_slices(l_max: int):
    out, o = [], 0
    for l in range(l_max + 1):
        out.append((l, o, o + 2 * l + 1))
        o += 2 * l + 1
    return out


def _m0_index(l_max: int):
    """Index of the m=0 component of each l in the stacked irreps."""
    return jnp.asarray([l * l + l for l in range(l_max + 1)])


def init_params(key, cfg: EquiformerV2Config):
    L, C, H = cfg.l_max, cfg.d_hidden, cfg.n_heads
    n_l = L + 1
    keys = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[i], 8)
        so2 = {"m0": jax.random.normal(ks[0], (n_l, C, n_l, C)) * ((n_l * C) ** -0.5)}
        for m in range(1, cfg.m_max + 1):
            lm = L + 1 - m
            so2[f"m{m}_r"] = jax.random.normal(ks[1], (lm, C, lm, C)) * ((lm * C) ** -0.5)
            so2[f"m{m}_i"] = jax.random.normal(ks[2], (lm, C, lm, C)) * ((lm * C) ** -0.5)
        layers.append({
            "so2": so2,
            "radial": init_mlp(ks[3], [cfg.n_rbf, C, n_l * C]),
            "attn": init_mlp(ks[4], [2 * n_l * C + cfg.n_rbf, C, H]),
            "ln_scale": jnp.ones((n_l, C)),
            "gate": init_mlp(ks[5], [C, C, L * C]),   # gates for l>=1 blocks
            "ffn": {
                "w1": jax.random.normal(ks[6], (n_l, C, C)) * (C ** -0.5),
                "w2": jax.random.normal(ks[7], (n_l, C, C)) * (C ** -0.5),
            },
        })
    return {
        "embed": jax.random.normal(keys[-3], (cfg.n_species, C)) * 0.02,
        "layers": layers,
        "out_head": init_mlp(keys[-2], [C, C, cfg.d_out]),
    }


def _rbf(dist, cfg: EquiformerV2Config):
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = cfg.n_rbf / cfg.cutoff
    return jnp.exp(-gamma * (dist[..., None] - centers) ** 2)


def _equiv_layer_norm(x, scale, l_max, eps=1e-6):
    """Normalize each l-block by its RMS over (m, channel); learnable per
    (l, channel) scale. Equivariant: the norm is rotation-invariant."""
    outs = []
    for l, a, b in _l_slices(l_max):
        blk = x[:, a:b]
        rms = jnp.sqrt(jnp.mean(blk * blk, axis=(1, 2), keepdims=True) + eps)
        outs.append(blk / rms * scale[l][None, None, :])
    return jnp.concatenate(outs, axis=1)


def _so2_conv(xr, so2, radial_gate, cfg: EquiformerV2Config):
    """m-sparse SO(2) linear map in the edge-aligned frame.

    xr: (E, K, C) rotated irreps. radial_gate: (E, n_l, C) per-(l,channel)
    distance modulation. Output has only m ≤ m_max populated (eSCN truncation).
    """
    L = cfg.l_max
    out = jnp.zeros_like(xr)
    # m = 0: one row per l
    m0_idx = _m0_index(L)
    x0 = xr[:, m0_idx]                                  # (E, n_l, C)
    y0 = jnp.einsum("elc,lckd->ekd", x0, so2["m0"])     # (E, n_l, C)
    y0 = y0 * radial_gate
    out = out.at[:, m0_idx].set(y0)
    # m >= 1: complex pairs (c_{l,+m}, c_{l,-m})
    for m in range(1, cfg.m_max + 1):
        ls = list(range(m, L + 1))
        ip = jnp.asarray([l * l + l + m for l in ls])
        im = jnp.asarray([l * l + l - m for l in ls])
        cr = xr[:, ip]                                  # (E, lm, C)
        ci = xr[:, im]
        wr, wi = so2[f"m{m}_r"], so2[f"m{m}_i"]
        yr = jnp.einsum("elc,lckd->ekd", cr, wr) - jnp.einsum("elc,lckd->ekd", ci, wi)
        yi = jnp.einsum("elc,lckd->ekd", cr, wi) + jnp.einsum("elc,lckd->ekd", ci, wr)
        gate_m = radial_gate[:, m:]                     # reuse l-major gate rows
        out = out.at[:, ip].set(yr * gate_m)
        out = out.at[:, im].set(yi * gate_m)
    return out


def _edge_logits(xs, xd, lp, cfg, mats, rbf, emask):
    """Attention logits for a chunk of (pre-gathered) edges: (Ec, H)."""
    m0_idx = _m0_index(cfg.l_max)
    xr = blockdiag_apply(mats, xs.astype(jnp.float32))
    inv_s = xr[:, m0_idx].reshape(xr.shape[0], -1)
    xdr = blockdiag_apply(mats, xd.astype(jnp.float32))
    inv_d = xdr[:, m0_idx].reshape(xr.shape[0], -1)
    logits = mlp_apply(lp["attn"], jnp.concatenate([inv_s, inv_d, rbf], -1))
    return jnp.where(emask[:, None], logits, -jnp.inf)


def _edge_messages(xs, lp, cfg, mats, rbf, emask, alpha):
    """Attention-weighted eSCN messages for a chunk: (Ec, K, C)."""
    L, C, H = cfg.l_max, cfg.d_hidden, cfg.n_heads
    K = irreps_dim(L)
    xr = blockdiag_apply(mats, xs.astype(jnp.float32))
    radial = mlp_apply(lp["radial"], rbf, final_act=False)
    radial_gate = jax.nn.sigmoid(radial).reshape(-1, L + 1, C)
    msg_r = _so2_conv(xr, lp["so2"], radial_gate, cfg)
    msg = blockdiag_apply(mats, msg_r, transpose=True)  # rotate back
    msg = msg.reshape(msg.shape[0], K, H, C // H)
    msg = msg * alpha[:, None, :, None]
    return msg.reshape(msg.shape[0], K, C) * emask[:, None, None]


def forward(params, batch, cfg: EquiformerV2Config):
    """batch: species (N,) int32, pos (N,3), src/dst (E,), edge_mask, node_mask.
    Returns (N, d_out) invariant predictions."""
    L, C = cfg.l_max, cfg.d_hidden
    K = irreps_dim(L)
    species = batch["species"]
    pos = batch["pos"]
    src, dst = batch["src"], batch["dst"]
    emask = batch["edge_mask"]
    n = species.shape[0]
    E = src.shape[0]

    x = jnp.zeros((n, K, C))
    x = x.at[:, 0, :].set(jnp.take(params["embed"], species, axis=0))
    x = constrain(x, "nodes", None, None)

    rel = pos[src] - pos[dst]
    dist = jnp.linalg.norm(rel, axis=-1)
    # zero-length edges (self-loops / padding) carry no direction — mask them
    # (a radius graph has none; required for exact equivariance)
    emask = emask & (dist > 1e-8)
    safe_rel = jnp.where(emask[:, None], rel, jnp.asarray([0.0, 0.0, 1.0]))
    R = rotation_to_z(safe_rel)                          # (E, 3, 3)
    # geometry is an input, not a parameter: stop gradients so AD never
    # builds the O((l_max⁴)·E) Wigner-recursion transpose chain
    mats = [jax.lax.stop_gradient(constrain(m, "edges", None, None))
            for m in wigner_rotations(R, L)]
    rbf = jax.lax.stop_gradient(_rbf(dist, cfg) * emask[:, None])

    nc = cfg.edge_chunks
    assert E % nc == 0, (E, nc)
    Ec = E // nc
    psw = cfg.gather_mode == "psw_ring"
    mesh = None
    if psw:
        from ...graph.psw_ops import (local_edge_softmax, local_gather,
                                      local_scatter_sum, ring_gather)
        from ...sharding import current_rules
        mesh = current_rules().mesh
        assert mesh is not None, "psw_ring needs an active mesh"

    def chunked(arr):
        out = arr.reshape(nc, Ec, *arr.shape[1:])
        # keep chunks edge-sharded (reshape would otherwise let SPMD
        # replicate the full per-edge array)
        return constrain(out, None, "edges", *([None] * (arr.ndim - 1)))

    mats_ch = [chunked(m) for m in mats] if nc > 1 else None
    rbf_ch = chunked(rbf) if nc > 1 else None
    emask_ch = chunked(emask) if nc > 1 else None
    dst_ch = chunked(dst) if nc > 1 else None

    def layer(x, lp):
        # gather once per layer: remote sources via the PSW ring; local
        # destinations (PAL guarantee) are gathered per chunk
        xb = x.astype(jnp.bfloat16) if psw else x
        if psw:
            # bf16 through the ring: halves the ring's ICI bytes and the
            # per-edge gathered state
            xs_all = ring_gather(xb, src, mesh)
        else:
            xs_all = jnp.take(x, src, axis=0)
        xs_all = constrain(xs_all, "edges", None, None)

        def gather_d(dst_c):
            if psw:
                return local_gather(xb, dst_c, mesh)
            return jnp.take(x, dst_c, axis=0)

        if nc == 1:
            logits = _edge_logits(xs_all, gather_d(dst), lp, cfg, mats, rbf,
                                  emask)
        else:
            xs_ch = chunked(xs_all)

            def logits_chunk(c):
                return _edge_logits(c["xs"], gather_d(c["dst"]), lp, cfg,
                                    c["mats"], c["rbf"], c["emask"])

            logits = jax.lax.map(
                jax.checkpoint(logits_chunk),
                {"xs": xs_ch, "dst": dst_ch, "mats": mats_ch, "rbf": rbf_ch,
                 "emask": emask_ch}).reshape(E, -1)
        if psw:
            alpha = local_edge_softmax(logits, dst, n, mesh)
        else:
            alpha = jax.vmap(lambda s: edge_softmax(s, dst, n),
                             in_axes=1, out_axes=1)(logits)   # (E, H)
        alpha = jnp.where(emask[:, None], alpha, 0.0)

        def scatter(msg, d):
            if psw:
                return local_scatter_sum(msg, d, n, mesh)
            return scatter_sum(msg, d, n)

        if nc == 1:
            msg = _edge_messages(xs_all, lp, cfg, mats, rbf, emask, alpha)
            agg = scatter(msg, dst)
        else:
            def scan_body(acc, c):
                msg = jax.checkpoint(_edge_messages, static_argnums=(2,))(
                    c["xs"], lp, cfg, c["mats"], c["rbf"], c["emask"],
                    c["alpha"])
                return acc + scatter(msg, c["dst"]), None

            agg, _ = jax.lax.scan(
                scan_body, jnp.zeros((n, K, C)),
                {"xs": xs_ch, "mats": mats_ch, "rbf": rbf_ch,
                 "emask": emask_ch, "alpha": chunked(alpha), "dst": dst_ch})
        return agg

    def full_layer(x, lp):
        agg = layer(x, lp)
        x = x + agg
        x = _equiv_layer_norm(x, lp["ln_scale"], L)

        # gated equivariant FFN: per-l channel mixing
        h_blocks = [
            jnp.einsum("nmc,cd->nmd", x[:, a:b], lp["ffn"]["w1"][l])
            for l, a, b in _l_slices(L)
        ]
        inv = jax.nn.silu(h_blocks[0][:, 0])            # (N, C) invariant
        gates = jax.nn.sigmoid(mlp_apply(lp["gate"], inv)).reshape(n, L, C)
        outs = []
        for l, a, b in _l_slices(L):
            blk = h_blocks[l]
            if l == 0:
                blk = jax.nn.silu(blk)
            else:
                blk = blk * gates[:, l - 1][:, None, :]
            outs.append(jnp.einsum("nmc,cd->nmd", blk, lp["ffn"]["w2"][l]))
        x = x + jnp.concatenate(outs, axis=1)
        return constrain(x, "nodes", None, None)

    # ONE scan over stacked layer params (a python loop would emit a
    # separate while loop per layer whose buffers XLA does not reuse)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params["layers"])
    body = jax.checkpoint(full_layer) if cfg.remat_layers else full_layer
    x, _ = jax.lax.scan(lambda x, lp: (body(x, lp), None), x, stacked)

    inv_out = x[:, 0]                                   # l=0 invariant channel
    return mlp_apply(params["out_head"], inv_out)
