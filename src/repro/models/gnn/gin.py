"""Graph Isomorphism Network (Xu et al., arXiv:1810.00826), TU-dataset config:
n_layers=5, d_hidden=64, sum aggregator, learnable eps; graph-level readout
sums per-layer node embeddings (jumping knowledge) as in the paper.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ...graph.segment_ops import scatter_sum
from ...sharding import constrain
from .common import init_mlp, mlp_apply, layer_norm


@dataclasses.dataclass(frozen=True)
class GINConfig:
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 16
    n_classes: int = 8
    readout: str = "graph"       # node | graph
    edge_chunks: int = 1         # PSW edge chunking for huge partitions


def init_params(key, cfg: GINConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "mlp": init_mlp(keys[i], [d, d, d]),
            "eps": jnp.zeros(()),                       # learnable ε
        })
    heads = jax.random.split(keys[-1], cfg.n_layers + 1)
    return {
        "encoder": init_mlp(keys[-2], [cfg.d_in, d]),
        "layers": layers,
        # per-layer readout heads (paper's sum-of-layers readout)
        "heads": [init_mlp(k, [d, cfg.n_classes]) for k in heads],
    }


def forward(params, batch, cfg: GINConfig):
    x = mlp_apply(params["encoder"], batch["x"], final_act=True)
    x = constrain(x, "nodes", None)
    src, dst = batch["src"], batch["dst"]
    emask = batch["edge_mask"].astype(x.dtype)[:, None]
    nmask = batch["node_mask"].astype(x.dtype)[:, None]
    n = x.shape[0]

    layer_reps = [x]
    for lp in params["layers"]:
        if cfg.edge_chunks == 1:
            agg = scatter_sum(x[src] * emask, dst, n)
        else:
            from ...graph.chunked import multi_aggregate_chunked
            acc = multi_aggregate_chunked(
                lambda src, _x=x: _x[src],
                {"dst": dst, "mask": batch["edge_mask"], "src": src},
                n, cfg.d_hidden, ("sum",), chunks=cfg.edge_chunks)
            agg = acc["sum"].astype(x.dtype)
        h = (1.0 + lp["eps"]) * x + agg
        x = mlp_apply(lp["mlp"], h, final_act=True)
        x = layer_norm(x) * nmask
        x = constrain(x, "nodes", None)
        layer_reps.append(x)

    if cfg.readout == "graph":
        out = 0.0
        for rep, head in zip(layer_reps, params["heads"]):
            pooled = (rep * nmask).sum(0, keepdims=True)
            out = out + mlp_apply(head, pooled)
        return out
    out = 0.0
    for rep, head in zip(layer_reps, params["heads"]):
        out = out + mlp_apply(head, rep)
    return out
