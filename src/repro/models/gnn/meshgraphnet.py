"""MeshGraphNet (Pfaff et al., arXiv:2010.03409): encode-process-decode with
15 message-passing blocks, d_hidden=128, 2-layer MLPs + LayerNorm, residual
edge and node updates, sum aggregation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ...graph.segment_ops import scatter_sum
from ...sharding import constrain
from .common import init_mlp, mlp_apply, layer_norm


@dataclasses.dataclass(frozen=True)
class MeshGraphNetConfig:
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 16
    d_edge_in: int = 8
    d_out: int = 3
    edge_chunks: int = 1         # PSW edge chunking for huge partitions
    remat_blocks: bool = False   # checkpoint processor blocks (huge graphs)


def _mlp_dims(cfg, d_in):
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers


def init_params(key, cfg: MeshGraphNetConfig):
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i])
        blocks.append({
            "edge_mlp": init_mlp(k1, _mlp_dims(cfg, 3 * cfg.d_hidden)),
            "node_mlp": init_mlp(k2, _mlp_dims(cfg, 2 * cfg.d_hidden)),
        })
    return {
        "node_encoder": init_mlp(keys[-3], _mlp_dims(cfg, cfg.d_node_in)),
        "edge_encoder": init_mlp(keys[-2], _mlp_dims(cfg, cfg.d_edge_in)),
        "blocks": blocks,
        "decoder": init_mlp(keys[-1], [cfg.d_hidden, cfg.d_hidden, cfg.d_out]),
    }


def forward(params, batch, cfg: MeshGraphNetConfig):
    src, dst = batch["src"], batch["dst"]
    emask = batch["edge_mask"].astype(jnp.float32)[:, None]
    n = batch["x"].shape[0]

    h = layer_norm(mlp_apply(params["node_encoder"], batch["x"], final_act=True))
    e = layer_norm(mlp_apply(params["edge_encoder"], batch["edge_attr"],
                             final_act=True))
    h = constrain(h, "nodes", None)
    e = constrain(e, "edges", None)

    nc = cfg.edge_chunks

    def block(carry, blk):
        h, e = carry
        if nc == 1:
            e_in = jnp.concatenate([e, h[src], h[dst]], axis=-1)
            e = layer_norm(e + mlp_apply(blk["edge_mlp"], e_in,
                                         final_act=True)) * emask
            agg = scatter_sum(e, dst, n)
        else:
            def chunk_step(acc, c):
                e_in = jnp.concatenate([c["e"], h[c["src"]], h[c["dst"]]], -1)
                e_new = layer_norm(
                    c["e"] + mlp_apply(blk["edge_mlp"], e_in, final_act=True)
                ) * c["m"][:, None]
                return acc + scatter_sum(e_new, c["dst"], n), e_new

            ch = lambda a: constrain(
                a.reshape(nc, a.shape[0] // nc, *a.shape[1:]),
                None, "edges", *([None] * (a.ndim - 1)))
            chunks = {"e": ch(e), "src": ch(src), "dst": ch(dst),
                      "m": ch(batch["edge_mask"].astype(e.dtype))}
            agg, e_new = jax.lax.scan(
                lambda a, c: jax.checkpoint(chunk_step)(a, c),
                jnp.zeros((n, e.shape[-1])), chunks)
            e = e_new.reshape(e.shape)
        n_in = jnp.concatenate([h, agg], axis=-1)
        h = layer_norm(h + mlp_apply(blk["node_mlp"], n_in, final_act=True))
        h = constrain(h, "nodes", None)
        e = constrain(e, "edges", None)
        return h, e

    # ONE scan over stacked blocks (separate per-layer while loops would
    # each hold their own chunk-scan buffers)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params["blocks"])
    body = jax.checkpoint(block) if cfg.remat_blocks else block
    (h, e), _ = jax.lax.scan(lambda c, b: (body(c, b), None), (h, e), stacked)

    return mlp_apply(params["decoder"], h)
