"""Principal Neighbourhood Aggregation (Corso et al., arXiv:2004.05718).

n_layers=4, d_hidden=75, aggregators={mean,max,min,std},
scalers={identity, amplification, attenuation} — 12 aggregate channels per
message dim, combined with a linear 'post' layer per PNA layer.
Message passing is PAL-ordered gather + segment reductions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ...graph.segment_ops import aggregate_multi, degree
from ...sharding import constrain
from .common import init_mlp, mlp_apply, layer_norm

AGGREGATORS = ("mean", "max", "min", "std")
SCALERS = ("identity", "amplification", "attenuation")


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 16
    n_classes: int = 8
    delta: float = 2.5           # avg log-degree normalizer (dataset statistic)
    readout: str = "node"        # node | graph
    edge_chunks: int = 1         # PSW edge chunking for huge partitions


def init_params(key, cfg: PNAConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    n_ch = len(AGGREGATORS) * len(SCALERS)
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i])
        layers.append({
            "pre": init_mlp(k1, [2 * d, d]),          # msg = MLP([h_u, h_v])
            "post": init_mlp(k2, [n_ch * d + d, d]),  # combine with self
        })
    return {
        "encoder": init_mlp(keys[-2], [cfg.d_in, d]),
        "layers": layers,
        "decoder": init_mlp(keys[-1], [d, d, cfg.n_classes]),
    }


def forward(params, batch, cfg: PNAConfig):
    from ...graph.chunked import fold_aggregate, multi_aggregate_chunked

    x = mlp_apply(params["encoder"], batch["x"], final_act=True)
    x = constrain(x, "nodes", None)
    src, dst = batch["src"], batch["dst"]
    n = x.shape[0]
    deg = degree(jnp.where(batch["edge_mask"], dst, n - 1), n)
    logd = jnp.log1p(deg)[:, None]
    amp = logd / cfg.delta
    att = cfg.delta / jnp.maximum(logd, 1e-6)

    for lp in params["layers"]:
        def msg_fn(src, dsti, _x=x, _lp=lp):
            msg_in = jnp.concatenate([_x[src], _x[dsti]], axis=-1)
            return mlp_apply(_lp["pre"], msg_in, final_act=True)

        acc = multi_aggregate_chunked(
            msg_fn,
            {"dst": dst, "mask": batch["edge_mask"], "src": src, "dsti": dst},
            n, cfg.d_hidden, AGGREGATORS, chunks=cfg.edge_chunks)
        agg = fold_aggregate(acc, AGGREGATORS).astype(x.dtype)  # (N, 4d)
        scaled = jnp.concatenate([agg, agg * amp, agg * att], -1)  # (N, 12d)
        scaled = constrain(scaled, "nodes", None)
        h = mlp_apply(lp["post"], jnp.concatenate([x, scaled], -1))
        x = layer_norm(x + h)
        x = constrain(x, "nodes", None)

    if cfg.readout == "graph":
        pooled = (x * batch["node_mask"][:, None]).sum(0, keepdims=True)
        return mlp_apply(params["decoder"], pooled)
    return mlp_apply(params["decoder"], x)
