"""Real spherical-harmonic rotation matrices (Wigner D in the real basis).

Ivanic & Ruedenberg recursion (J. Phys. Chem. 1996, with 1998 errata):
builds the (2l+1)x(2l+1) rotation of real SH coefficients for each l from
the l=1 matrix, batched over edges with jnp ops (static index tables, so it
jits and differentiates). This is the rotation step of eSCN / EquiformerV2:
rotate each edge's features into the edge-aligned frame where the SO(2)
convolution is m-sparse, then rotate back.

Correctness is property-tested: composition homomorphism, orthogonality,
and agreement with explicit real-SH polynomials for l<=2.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import List

import jax
import jax.numpy as jnp

__all__ = ["wigner_rotations", "rotation_to_z", "blockdiag_apply", "irreps_dim"]


def irreps_dim(l_max: int) -> int:
    return (l_max + 1) ** 2


def _p_entry(i, l, mu, mp, Mlm1, M1):
    """Helper P^l_{i,mu,mp} of the recursion; Mlm1: (..., 2l-1, 2l-1)."""
    # M1 is indexed by m in {-1,0,1} -> offset +1; Mlm1 by offset l-1
    off = l - 1
    if mp == l:
        return (M1[..., i + 1, 2] * Mlm1[..., mu + off, 2 * l - 2]
                - M1[..., i + 1, 0] * Mlm1[..., mu + off, 0])
    if mp == -l:
        return (M1[..., i + 1, 2] * Mlm1[..., mu + off, 0]
                + M1[..., i + 1, 0] * Mlm1[..., mu + off, 2 * l - 2])
    return M1[..., i + 1, 1] * Mlm1[..., mu + off, mp + off]


def _uvw(l, m, mp):
    am = abs(m)
    if abs(mp) < l:
        denom = (l + mp) * (l - mp)
    else:
        denom = (2 * l) * (2 * l - 1)
    u = math.sqrt((l + m) * (l - m) / denom)
    d_m0 = 1.0 if m == 0 else 0.0
    v = 0.5 * math.sqrt((1 + d_m0) * (l + am - 1) * (l + am) / denom) * (1 - 2 * d_m0)
    w = -0.5 * math.sqrt((l - am - 1) * (l - am) / denom) * (1 - d_m0)
    return u, v, w


def _recurse(Mlm1, M1, l):
    rows = []
    for m in range(-l, l + 1):
        row = []
        for mp in range(-l, l + 1):
            u, v, w = _uvw(l, m, mp)
            term = 0.0
            if u != 0.0:
                term = term + u * _p_entry(0, l, m, mp, Mlm1, M1)
            if v != 0.0:
                if m == 0:
                    vv = (_p_entry(1, l, 1, mp, Mlm1, M1)
                          + _p_entry(-1, l, -1, mp, Mlm1, M1))
                elif m > 0:
                    d = 1.0 if m == 1 else 0.0
                    vv = (_p_entry(1, l, m - 1, mp, Mlm1, M1) * math.sqrt(1 + d)
                          - _p_entry(-1, l, -m + 1, mp, Mlm1, M1) * (1 - d))
                else:
                    d = 1.0 if m == -1 else 0.0
                    vv = (_p_entry(1, l, m + 1, mp, Mlm1, M1) * (1 - d)
                          + _p_entry(-1, l, -m - 1, mp, Mlm1, M1) * math.sqrt(1 + d))
                term = term + v * vv
            if w != 0.0:
                if m > 0:
                    ww = (_p_entry(1, l, m + 1, mp, Mlm1, M1)
                          + _p_entry(-1, l, -m - 1, mp, Mlm1, M1))
                else:
                    ww = (_p_entry(1, l, m - 1, mp, Mlm1, M1)
                          - _p_entry(-1, l, -m + 1, mp, Mlm1, M1))
                term = term + w * ww
            row.append(term)
        rows.append(jnp.stack(row, axis=-1))
    return jnp.stack(rows, axis=-2)


def wigner_rotations(R: jnp.ndarray, l_max: int) -> List[jnp.ndarray]:
    """R: (..., 3, 3) rotation matrices → [M_0, ..., M_lmax], each
    (..., 2l+1, 2l+1), rotating real SH coefficient vectors."""
    perm = jnp.asarray([1, 2, 0])  # real-SH l=1 basis order (y, z, x)
    M1 = R[..., perm[:, None], perm[None, :]]
    mats = [jnp.ones(R.shape[:-2] + (1, 1), R.dtype), M1]
    for l in range(2, l_max + 1):
        mats.append(_recurse(mats[-1], M1, l))
    return mats[: l_max + 1]


def rotation_to_z(direction: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """Rotation R with R @ d = ẑ for unit vectors d: (..., 3).

    ẑ is the principal axis of this real-SH convention (m=0 components are
    z-aligned; rotations about ẑ mix only within (m, -m) pairs), so the
    SO(2) convolution's m-sparsity holds exactly in the aligned frame.
    Rodrigues formula with robust handling of d ≈ ±ẑ.
    """
    d = direction / jnp.maximum(jnp.linalg.norm(direction, axis=-1, keepdims=True), eps)
    z = jnp.zeros_like(d).at[..., 2].set(1.0)
    v = jnp.cross(d, z)
    c = d[..., 2]                              # cos = d · ẑ
    s2 = jnp.sum(v * v, axis=-1)               # sin²
    # K = [v]_x ; R = I + K + K² (1-c)/s²
    zeros = jnp.zeros_like(c)
    K = jnp.stack([
        jnp.stack([zeros, -v[..., 2], v[..., 1]], -1),
        jnp.stack([v[..., 2], zeros, -v[..., 0]], -1),
        jnp.stack([-v[..., 1], v[..., 0], zeros], -1),
    ], -2)
    eye = jnp.broadcast_to(jnp.eye(3, dtype=d.dtype), K.shape)
    factor = jnp.where(s2 > eps, (1.0 - c) / jnp.maximum(s2, eps), 0.0)
    R = eye + K + factor[..., None, None] * (K @ K)
    # antiparallel (d = -ẑ): rotate π about x̂
    flip = jnp.broadcast_to(
        jnp.asarray([[1.0, 0, 0], [0, -1.0, 0], [0, 0, -1.0]], d.dtype), K.shape)
    anti = (c < -1.0 + 1e-6)[..., None, None]
    return jnp.where(anti, flip, R)


def blockdiag_apply(mats: List[jnp.ndarray], x: jnp.ndarray,
                    transpose: bool = False) -> jnp.ndarray:
    """Apply per-l rotations to stacked irreps features.

    mats[l]: (..., 2l+1, 2l+1); x: (..., (lmax+1)^2, C). Returns same shape.
    """
    outs = []
    o = 0
    for l, M in enumerate(mats):
        k = 2 * l + 1
        blk = x[..., o:o + k, :]
        Ml = jnp.swapaxes(M, -1, -2) if transpose else M
        outs.append(jnp.einsum("...ij,...jc->...ic", Ml, blk))
        o += k
    return jnp.concatenate(outs, axis=-2)
