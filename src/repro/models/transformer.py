"""Decoder-only transformer stack: dense + MoE, GQA/MQA, qk-norm, RoPE.

Pure-pytree params (no flax), `jax.lax.scan` over stacked layers (compact
HLO at 88 layers / 512 devices), blockwise-chunked attention (flash-style
online softmax in XLA) with an optional Pallas kernel path, KV-cache decode,
and MoE with sort-based capacity dispatch (expert-parallel over the `model`
mesh axis — the PAL interval-exchange pattern, see DESIGN.md §4).

Logical sharding axes are annotated via repro.sharding; the same code runs
unsharded on the CPU test device.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..sharding import constrain

__all__ = [
    "MoEConfig",
    "TransformerConfig",
    "init_params",
    "forward",
    "loss_fn",
    "init_cache",
    "prefill",
    "decode_step",
    "param_logical_axes",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    aux_coef: float = 0.01
    router_dtype: Any = jnp.float32
    # §Perf H4: which dim of the expert FFN is sharded over `model`:
    #   "expert" — classic EP (E sharded; dispatch crosses the model axis)
    #   "ffn"    — f sharded; the dispatch gather/scatter stays group-local
    #              in BOTH directions, at the cost of one eout all-reduce
    ep_mode: str = "expert"


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None            # default d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "dots"                     # none | dots | full
    q_chunk: int = 512
    kv_chunk: int = 1024
    norm_eps: float = 1e-6
    attention_impl: str = "xla"             # xla (blockwise) | pallas

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the vocab dim shards evenly over the model
        axis (standard padded-vocab; padded logits are masked in the loss)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (h * dh) * 2 + d * (kv * dh) * 2  # wq,wo + wk,wv
        if self.moe is None:
            mlp = 3 * d * self.d_ff
        else:
            mlp = self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        per_layer = attn + mlp + 2 * d + (2 * dh if self.qk_norm else 0)
        return self.n_layers * per_layer + 2 * self.padded_vocab * d + d

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.n_params
        d = self.d_model
        dense = self.n_params - self.n_layers * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        return dense + self.n_layers * self.moe.top_k * 3 * d * self.moe.d_ff_expert


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x, positions, theta):
    """Rotary embedding. x: (..., seq, heads, d_head); positions: (..., seq)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def blockwise_attention(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int,
                        q_pos0=0, scale: Optional[float] = None):
    """Flash-style attention in pure XLA: O(S·chunk) memory, exact softmax.

    q: (B, S, H, Dh); k, v: (B, T, Hkv, Dh). GQA via head grouping. Chunks
    must divide S and T (configs are chosen 128-aligned). Differentiable;
    pairs with remat for the backward pass.
    """
    B, S, H, Dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    nq, nk = S // q_chunk, T // kv_chunk
    qr = q.reshape(B, nq, q_chunk, Hkv, G, Dh)

    def q_block(qi, q_blk):
        q_idx = q_pos0 + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, num, den = carry
            k_blk = lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            v_blk = lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            if causal:
                kv_idx = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = q_idx[:, None] >= kv_idx[None, :]
                s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            num_new = num * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            den_new = den * corr + p.sum(axis=-1)
            return (m_new, num_new, den_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), -jnp.inf, jnp.float32)
        num0 = jnp.zeros((B, Hkv, G, q_chunk, Dh), jnp.float32)
        den0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        (m, num, den), _ = lax.scan(kv_step, (m0, num0, den0), jnp.arange(nk))
        out = num / jnp.maximum(den[..., None], 1e-30)      # (B,Hkv,G,qc,Dh)
        return out.transpose(0, 3, 1, 2, 4)                 # (B,qc,Hkv,G,Dh)

    outs = lax.map(lambda args: q_block(*args),
                   (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, Dh)
    return out.astype(q.dtype)


def attention(params, x, cfg: TransformerConfig, positions, kv_cache=None,
              cache_pos=None):
    """Self-attention. Train/prefill when kv_cache is None; decode otherwise.

    Returns (out, new_kv) where new_kv is (k, v) for cache construction.
    """
    B, S, d = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = cfg.compute_dtype
    q = (x @ params["wq"].astype(cdt)).reshape(B, S, H, Dh)
    k = (x @ params["wk"].astype(cdt)).reshape(B, S, Hkv, Dh)
    v = (x @ params["wv"].astype(cdt)).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"].astype(cdt), cfg.norm_eps)
        k = rms_norm(k, params["k_norm"].astype(cdt), cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, None, None)

    if kv_cache is None:
        out = blockwise_attention(q, k, v, causal=True,
                                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        new_kv = (k, v)
    else:
        ck, cv = kv_cache                                   # (B, T, Hkv, Dh)
        ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, 1)
        cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, 1)
        T = ck.shape[1]
        G = H // Hkv
        qg = q.reshape(B, S, Hkv, G, Dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                       ck.astype(jnp.float32)) * (Dh ** -0.5)
        kv_idx = jnp.arange(T)
        # causal within the new tokens + all previous cache entries
        qpos = cache_pos + jnp.arange(S)
        mask = kv_idx[None, :] <= qpos[:, None]             # (S, T)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, cv.astype(jnp.float32))
        out = out.reshape(B, S, H, Dh).astype(cdt)
        new_kv = (ck, cv)

    out = constrain(out, "batch", None, "model", None)
    y = out.reshape(B, S, H * Dh) @ params["wo"].astype(cdt)
    return y, new_kv


def dense_mlp(params, x, cfg: TransformerConfig):
    cdt = cfg.compute_dtype
    g = x @ params["w_gate"].astype(cdt)
    u = x @ params["w_up"].astype(cdt)
    g = constrain(g, "batch", None, "model")
    u = constrain(u, "batch", None, "model")
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(cdt)


def moe_mlp(params, x, cfg: TransformerConfig):
    """Sort-based capacity MoE dispatch (GShard-style, gather/scatter instead
    of one-hot einsum). Expert dim sharded over `model` (EP) — XLA inserts
    the token all-to-all, the PAL interval-exchange pattern.

    Long sequences are processed in sequence chunks (MoE is pointwise over
    tokens, so chunking is exact) to bound the dispatch working set.

    x: (B, S, d). Returns (out, aux_loss).
    """
    mo = cfg.moe
    B, S, d = x.shape
    s_chunk = 2048
    if S > s_chunk and S % s_chunk == 0:
        nc = S // s_chunk
        xc = constrain(x.reshape(B, nc, s_chunk, d).swapaxes(0, 1),
                       None, "batch", None, None)

        def body(_, xcc):
            o, a = _moe_core(params, xcc, cfg)
            return None, (o, a)

        _, (outs, auxes) = jax.lax.scan(jax.checkpoint(body), None, xc)
        out = constrain(outs, None, "batch", None, None)
        out = out.swapaxes(0, 1).reshape(B, S, d)
        return out, auxes.mean()
    return _moe_core(params, x, cfg)


def _moe_core(params, x, cfg: TransformerConfig):
    """Local-capacity dispatch (§Perf H2, beyond-paper): tokens are grouped
    by DP shard; routing, the dispatch gather, and the combine scatter are
    all GROUP-LOCAL (zero dispatch collectives — only the expert einsum is
    sharded over `model`). Per-group capacity approximates global capacity
    (standard local-dispatch MoE; with one group it is exactly GShard)."""
    mo = cfg.moe
    B, S, d = x.shape
    t = B * S
    E, K = mo.n_experts, mo.top_k
    cdt = cfg.compute_dtype

    from ..sharding import current_rules
    mesh = current_rules().mesh
    dp = 1
    if mesh is not None:
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                dp *= mesh.shape[ax]
    if B % dp != 0:
        dp = 1
    tg = t // dp
    cap = int(mo.capacity_factor * tg * K / E + 0.5)
    cap = max(8, -(-cap // 8) * 8)
    xt = constrain(x.reshape(dp, tg, d), "batch", None, None)

    def route_group(xg):
        """xg: (tg, d) -> (ein (E, cap, d), tfs, gfs, me, ce)."""
        logits = (xg.astype(mo.router_dtype)
                  @ params["router"].astype(mo.router_dtype))
        probs = jax.nn.softmax(logits, axis=-1)             # (tg, E)
        gates, idx = lax.top_k(probs, K)                    # (tg, K)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros(E, probs.dtype).at[idx.reshape(-1)].add(1.0) / (tg * K)

        expert_of = idx.reshape(-1)                         # (tg*K,)
        order = jnp.argsort(expert_of)                      # stable
        sorted_e = expert_of[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
        pos_in_e = jnp.arange(tg * K) - seg_start[sorted_e]
        ok = pos_in_e < cap
        slot = jnp.where(ok, sorted_e * cap + pos_in_e, E * cap)
        tok = order // K

        # gather-based dispatch: invert slot->token with a cheap int scatter
        # (empty slots -> row 0 with gate 0)
        token_for_slot = jnp.zeros((E * cap + 1,), jnp.int32)
        token_for_slot = token_for_slot.at[slot].set(tok.astype(jnp.int32))
        gate_for_slot = jnp.zeros((E * cap + 1,), cdt)
        gate_for_slot = gate_for_slot.at[slot].set(
            (gates.reshape(-1)[order] * ok).astype(cdt))
        tfs = token_for_slot[: E * cap]
        gfs = gate_for_slot[: E * cap]
        ein = xg.astype(cdt)[tfs].reshape(E, cap, d)
        return ein, tfs, gfs, me, ce

    ein, tfs, gfs, me, ce = jax.vmap(route_group)(xt)
    aux = mo.aux_coef * E * jnp.sum(me.mean(0) * ce.mean(0))
    exp_ax = "experts" if mo.ep_mode == "expert" else None
    ein = constrain(ein, "batch", exp_ax, None, None)       # (dp, E, cap, d)

    g = jnp.einsum("gecd,edf->gecf", ein, params["w_gate"].astype(cdt))
    u = jnp.einsum("gecd,edf->gecf", ein, params["w_up"].astype(cdt))
    if mo.ep_mode == "ffn":
        g = constrain(g, "batch", None, None, "model")
        u = constrain(u, "batch", None, None, "model")
    h = jax.nn.silu(g) * u
    eout = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(cdt))
    eout = constrain(eout, "batch", exp_ax, None, None)

    # combine: group-local weighted scatter-add back to token rows
    weighted = eout.reshape(dp, E * cap, d) * gfs[..., None]
    out = jax.vmap(lambda w, i: jax.ops.segment_sum(w, i, num_segments=tg))(
        weighted, tfs)
    out = constrain(out, "batch", None, None)
    return out.reshape(B, S, d), aux


def layer_fn(params, x, cfg: TransformerConfig, positions, kv_cache=None,
             cache_pos=None):
    cdt = cfg.compute_dtype
    h = rms_norm(x, params["ln1"].astype(cdt), cfg.norm_eps)
    a, new_kv = attention(params["attn"], h, cfg, positions, kv_cache, cache_pos)
    x = x + a
    h = rms_norm(x, params["ln2"].astype(cdt), cfg.norm_eps)
    if cfg.moe is None:
        m = dense_mlp(params["mlp"], h, cfg)
        aux = jnp.zeros((), jnp.float32)
    else:
        m, aux = moe_mlp(params["mlp"], h, cfg)
    x = x + m
    x = constrain(x, "batch", None, None)
    return x, new_kv, aux


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def _layer_shapes(cfg: TransformerConfig) -> Dict[str, Any]:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn = {
        "wq": (d, H * Dh), "wk": (d, Hkv * Dh), "wv": (d, Hkv * Dh),
        "wo": (H * Dh, d),
    }
    if cfg.qk_norm:
        attn["q_norm"] = (Dh,)
        attn["k_norm"] = (Dh,)
    if cfg.moe is None:
        mlp = {"w_gate": (d, cfg.d_ff), "w_up": (d, cfg.d_ff),
               "w_down": (cfg.d_ff, d)}
    else:
        E, f = cfg.moe.n_experts, cfg.moe.d_ff_expert
        mlp = {"router": (d, E), "w_gate": (E, d, f), "w_up": (E, d, f),
               "w_down": (E, f, d)}
    return {"attn": attn, "mlp": mlp, "ln1": (d,), "ln2": (d,)}


def init_params(key, cfg: TransformerConfig):
    """Stacked-layer params; eval_shape-friendly."""
    d = cfg.d_model
    shapes = _layer_shapes(cfg)

    def init_tree(key, tree, stack: Optional[int]):
        leaves, treedef = jax.tree.flatten(
            tree, is_leaf=lambda x: isinstance(x, tuple))
        keys = jax.random.split(key, len(leaves))
        out = []
        for k, shp in zip(keys, leaves):
            full = (stack, *shp) if stack else shp
            if len(shp) == 1:  # norm scales
                out.append(jnp.ones(full, cfg.param_dtype))
            else:
                fan_in = shp[-2] if len(shp) >= 2 else d
                out.append(jax.random.normal(k, full, cfg.param_dtype)
                           * (fan_in ** -0.5))
        return jax.tree.unflatten(treedef, out)

    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": jax.random.normal(k1, (cfg.padded_vocab, d), cfg.param_dtype) * 0.02,
        "layers": init_tree(k2, shapes, cfg.n_layers),
        "final_norm": jnp.ones((d,), cfg.param_dtype),
        "lm_head": jax.random.normal(k3, (cfg.padded_vocab, d), cfg.param_dtype)
        * (d ** -0.5),
    }


def param_logical_axes(cfg: TransformerConfig):
    """Pytree of logical-axis tuples mirroring init_params' structure."""
    attn = {
        "wq": ("fsdp", "model"), "wk": ("fsdp", "model"), "wv": ("fsdp", "model"),
        "wo": ("model", "fsdp"),
    }
    if cfg.qk_norm:
        attn["q_norm"] = (None,)
        attn["k_norm"] = (None,)
    if cfg.moe is None:
        mlp = {"w_gate": ("fsdp", "model"), "w_up": ("fsdp", "model"),
               "w_down": ("model", "fsdp")}
    elif cfg.moe.ep_mode == "ffn":
        mlp = {"router": ("fsdp", None), "w_gate": (None, "fsdp", "model"),
               "w_up": (None, "fsdp", "model"),
               "w_down": (None, "model", "fsdp")}
    else:
        mlp = {"router": ("fsdp", None), "w_gate": ("experts", "fsdp", None),
               "w_up": ("experts", "fsdp", None),
               "w_down": ("experts", None, "fsdp")}
    layer = {"attn": attn, "mlp": mlp, "ln1": (None,), "ln2": (None,)}
    # stacked layer dim is unsharded (leading axis of every layer leaf)
    layer = jax.tree.map(lambda ax: (None, *ax), layer,
                         is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": ("model", "fsdp"),
        "layers": layer,
        "final_norm": (None,),
        "lm_head": ("model", "fsdp"),
    }


# ---------------------------------------------------------------------------
# Forward / loss / decode
# ---------------------------------------------------------------------------
def _remat(fn, cfg: TransformerConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)


def forward(params, tokens, cfg: TransformerConfig):
    """tokens: (B, S) -> logits (B, S, vocab) in compute dtype."""
    B, S = tokens.shape
    cdt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    x = constrain(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lp):
        x, _, aux = layer_fn(lp, x, cfg, positions)
        return x, aux

    x, auxes = lax.scan(_remat(body, cfg), x, params["layers"])
    x = rms_norm(x, params["final_norm"].astype(cdt), cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"].astype(cdt))
    logits = constrain(logits, "batch", None, "model")
    return logits, auxes.sum()


def loss_fn(params, batch, cfg: TransformerConfig):
    """Mean next-token cross-entropy (+ MoE aux). batch: tokens, labels."""
    logits, aux = forward(params, batch["tokens"], cfg)
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:      # mask padded vocab rows
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce + aux


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, tokens, cfg: TransformerConfig, max_seq: int,
            cache_dtype=jnp.bfloat16):
    """Run the prompt, return (logits_last, cache)."""
    B, S = tokens.shape
    cdt = cfg.compute_dtype
    cache = init_cache(cfg, B, max_seq, dtype=cache_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lp):
        x, kv, _ = layer_fn(lp, x, cfg, positions)
        return x, kv

    x, (ks, vs) = lax.scan(_remat(body, cfg), x, params["layers"])
    cache["k"] = lax.dynamic_update_slice_in_dim(
        cache["k"], ks.astype(cache["k"].dtype), 0, 2)
    cache["v"] = lax.dynamic_update_slice_in_dim(
        cache["v"], vs.astype(cache["v"].dtype), 0, 2)
    x = rms_norm(x, params["final_norm"].astype(cdt), cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["lm_head"].astype(cdt))
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: TransformerConfig):
    """One decode step. tokens: (B, 1) int32; pos: () int32 cache position.
    Returns (logits (B, vocab), new_cache)."""
    B, S = tokens.shape
    cdt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    positions = jnp.broadcast_to(pos + jnp.arange(S), (B, S))

    def body(x, layer):
        lp, kv = layer
        x, new_kv, _ = layer_fn(lp, x, cfg, positions, kv_cache=kv, cache_pos=pos)
        return x, new_kv

    x, (ks, vs) = lax.scan(body, x, (params["layers"], (cache["k"], cache["v"])))
    x = rms_norm(x, params["final_norm"].astype(cdt), cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["lm_head"].astype(cdt))
    logits = constrain(logits, "batch", "model")
    return logits, {"k": ks, "v": vs}
