from .adamw import (AdamWConfig, adamw_init, adamw_update,
                    clip_by_global_norm, cosine_schedule, global_norm,
                    linear_warmup_cosine)
from .compression import compressed_psum_tree, ef_compress, ef_decompress
