"""AdamW + schedules + global-norm clipping (dependency-free pytree optimizer).

Optimizer state mirrors the param pytree, so the same logical-axis sharding
rules apply to m/v (FSDP'd optimizer states for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "linear_warmup_cosine", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr if schedule is None else cfg.lr * schedule(step)
    gnorm = jnp.asarray(0.0)
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


def cosine_schedule(total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return fn


def linear_warmup_cosine(warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_schedule(max(total_steps - warmup, 1), final_frac)
    def fn(step):
        w = jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)
        return w * cos(jnp.maximum(step - warmup, 0))
    return fn
