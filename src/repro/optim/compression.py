"""Gradient compression for the data-parallel all-reduce.

int8 error-feedback quantization (1-bit-Adam-family trick): each DP shard
quantizes its local gradient to int8 with a per-tensor scale before the
all-reduce, keeping the quantization residual locally and adding it to the
next step's gradient. Cuts DP all-reduce bytes 4x (fp32) / 2x (bf16) at
equal asymptotic convergence (error feedback keeps the bias bounded).

`compressed_psum` is the shard_map building block; `ef_compress/ef_residual`
are the pure parts, unit-tested on CPU.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_compress", "ef_decompress", "compressed_psum_tree"]


def ef_compress(g: jnp.ndarray, residual: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize (g + residual) to int8 with a per-tensor scale.
    Returns (q_int8, scale, new_residual)."""
    x = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_residual = x - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def ef_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(grads, residuals, axis_name: str):
    """Error-feedback int8 all-reduce of a gradient pytree over `axis_name`
    (use inside shard_map). Scales are all-reduced in fp32 (negligible bytes);
    payloads cross the interconnect as int8. Returns (mean_grads, residuals)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        q, scale, new_r = ef_compress(g, r)
        # int8 summation can overflow int8 — accumulate in int32
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.psum(scale, axis_name)
        # each shard used its own scale; approximate with the mean scale
        mean = total.astype(jnp.float32) * (scale_sum / n) / n
        return mean.astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
