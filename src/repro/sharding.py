"""Logical-axis sharding rules (MaxText-style, dependency-free).

Models annotate params/activations with *logical* axis names; a ShardingRules
instance maps them to mesh axes. Rules silently drop mesh axes that don't
exist on the current mesh (so the same model code runs on the single-pod
(data, model) mesh, the multi-pod (pod, data, model) mesh, and the 1-CPU test
device with no mesh at all).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "DEFAULT_RULES", "use_rules", "current_rules",
           "constrain", "spec_for", "named_sharding"]

Axis = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""

    rules: Dict[str, Axis]
    mesh: Optional[Mesh] = None

    def _resolve(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        ax = self.rules.get(logical)
        if ax is None or self.mesh is None:
            return None
        names = set(self.mesh.axis_names)
        if isinstance(ax, str):
            return ax if ax in names else None
        ax = tuple(a for a in ax if a in names)
        return ax if ax else None

    def spec(self, *logical_axes: Optional[str]) -> P:
        return P(*[self._resolve(a) for a in logical_axes])

    def sharding(self, *logical_axes: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical_axes))

    def constrain(self, x, *logical_axes: Optional[str]):
        """with_sharding_constraint if a mesh is active; identity otherwise."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical_axes)))


# Logical axes used across the framework:
#   batch      token/sample batch             -> pod+data (pure DP)
#   fsdp       param dim sharded FSDP-style   -> data
#   model      tensor-parallel dim            -> model (heads / mlp / vocab)
#   experts    MoE expert dim                 -> model (EP)
#   nodes      graph vertex-interval dim      -> pod+data+model (PAL intervals)
#   edges      graph edge dim                 -> pod+data+model (PAL partitions)
#   table      embedding-table row dim        -> model (PAL-hashed rows)
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "model": "model",
    "experts": "model",
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
    "table": "model",
    "seq": None,
}

_state = threading.local()


def current_rules() -> ShardingRules:
    r = getattr(_state, "rules", None)
    if r is None:
        r = ShardingRules(rules=dict(DEFAULT_RULES), mesh=None)
    return r


@contextlib.contextmanager
def use_rules(rules: ShardingRules):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def constrain(x, *logical_axes: Optional[str]):
    return current_rules().constrain(x, *logical_axes)


def spec_for(*logical_axes: Optional[str]) -> P:
    return current_rules().spec(*logical_axes)


def named_sharding(*logical_axes: Optional[str]):
    return current_rules().sharding(*logical_axes)
