"""Crash-consistency torture workload + prefix-equality oracle (ISSUE 7).

The workload is a DETERMINISTIC op stream against a live `ServiceDB`
(`wal_sync="always"`: every mutation call is fsync-durable before it
returns) with aggressive maintenance settings, so a short run crosses
every stage of the pipeline — buffer flush merges, partition persistence,
checkpoint phase A/B, store GC, WAL rotation/compaction. Ops are derived
purely from (seed, op index): the verifier re-generates the exact same
stream without any channel from the crashed process.

Crash points are injected via the failpoint registry's environment
channel: the test/bench driver sets `GRAPHDB_FAILPOINTS="<site>=crash@N"`
and runs `python -m repro.torture run <dbdir> ...` in a subprocess, which
dies mid-I/O with `os._exit(41)` — no cleanup, no flushing, the power-pull
analogue. After each acked batch the runner appends one line to an ORACLE
log (fsynced append: the ack itself is durable), so the driver knows a
lower bound on what recovery must reproduce.

The oracle (`verify`): recover with `GraphDB.open` in a fresh process and
require the recovered edge multiset to be bitwise-equal to the state after
SOME op-stream prefix k with k >= the acked count. `wal_sync="always"`
makes each op a durability point, so recovery to anything that is not an
exact op boundary — or to less than what was acked — is a correctness
bug, not bad luck.

Op stream (all derived from the seed):
  op 3i:   insert one batch of `batch_size` edges, unique src per edge
           (src = global edge index, so every (src, dst) pair is unique
           and every prefix state is distinct), with a float32 "w" column
           (exercises typed column records in the WAL).
  op 3i+1: delete the first edge of the PREVIOUS batch (i > 0) — each
           delete targets a distinct, known-live edge.
  op 3i+2: ack batch i to the oracle (not a db op; marks durability).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Tuple

import numpy as np

DEFAULT_BATCHES = 24
DEFAULT_BATCH_SIZE = 200
DEFAULT_SEED = 7

# aggressive maintenance: a ~5k-edge run crosses flush, checkpoint A/B,
# GC, and several WAL segment rotations
DB_KW = dict(
    n_partitions=16, n_levels=3, branching=4,
    buffer_cap=400, max_partition_edges=8000,
    persist_min_edges=256, wal_segment_bytes=16 << 10,
    wal_sync="always",
)
SERVICE_KW = dict(
    checkpoint_interval_ops=900,
    backpressure_edges=4000,
)


def max_id_for(batches: int, batch_size: int) -> int:
    return batches * batch_size + 1


def gen_batch(i: int, batch_size: int, seed: int,
              max_id: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batch i's edges: unique src ids (the global edge index), seeded
    random dst, seeded float32 weights."""
    rng = np.random.default_rng(seed * 1_000_003 + i)
    src = np.arange(i * batch_size, (i + 1) * batch_size, dtype=np.int64)
    dst = rng.integers(0, max_id, batch_size).astype(np.int64)
    w = rng.random(batch_size).astype(np.float32)
    return src, dst, w


def delete_target(i: int, batch_size: int, seed: int,
                  max_id: int) -> Tuple[int, int]:
    """The edge batch i's delete op removes: first edge of batch i-1."""
    src, dst, _ = gen_batch(i - 1, batch_size, seed, max_id)
    return int(src[0]), int(dst[0])


def reference_states(batches: int, batch_size: int, seed: int):
    """Yield (ops_done, sorted (src, dst) edge multiset) after every op
    boundary of the stream — the candidate durable prefixes."""
    max_id = max_id_for(batches, batch_size)
    srcs: List[np.ndarray] = []
    dsts: List[np.ndarray] = []
    deleted: List[Tuple[int, int]] = []
    ops = 0

    def state():
        if not srcs:
            return (np.empty(0, np.int64), np.empty(0, np.int64))
        s = np.concatenate(srcs)
        d = np.concatenate(dsts)
        keep = np.ones(s.shape[0], bool)
        for ds, dd in deleted:
            keep &= ~((s == ds) & (d == dd))
        s, d = s[keep], d[keep]
        order = np.lexsort((d, s))
        return (s[order], d[order])

    yield ops, state()
    for i in range(batches):
        src, dst, _ = gen_batch(i, batch_size, seed, max_id)
        srcs.append(src)
        dsts.append(dst)
        ops += 1
        yield ops, state()
        if i > 0:
            deleted.append(delete_target(i, batch_size, seed, max_id))
            ops += 1
            yield ops, state()


def total_ops(batches: int) -> int:
    return batches + max(0, batches - 1)


def run_workload(dbdir: str, oracle_path: str,
                 batches: int = DEFAULT_BATCHES,
                 batch_size: int = DEFAULT_BATCH_SIZE,
                 seed: int = DEFAULT_SEED) -> None:
    """The subprocess entry point: create the store, stream the ops, ack
    each batch to the oracle, clean close. A crash failpoint armed via
    GRAPHDB_FAILPOINTS kills the process anywhere along the way."""
    from .core.service import ServiceDB

    max_id = max_id_for(batches, batch_size)
    svc = ServiceDB.create(
        dbdir, max_id=max_id,
        column_dtypes={"w": np.float32},
        **SERVICE_KW, **DB_KW)
    with open(oracle_path, "a") as oracle:
        ops = 0
        for i in range(batches):
            src, dst, w = gen_batch(i, batch_size, seed, max_id)
            svc.insert_edges(src, dst, columns={"w": w})
            ops += 1
            if i > 0:
                ds, dd = delete_target(i, batch_size, seed, max_id)
                svc.delete_edge(ds, dd)
                ops += 1
            # the ack: this batch's ops were fsync-durable when the calls
            # returned (wal_sync="always"); make the ack itself durable
            oracle.write(f"{ops}\n")
            oracle.flush()
            os.fsync(oracle.fileno())
    svc.close()


def acked_ops(oracle_path: str) -> int:
    """Durable lower bound: the last fully-written ack line (a torn final
    line is ignored, exactly like a torn WAL record)."""
    if not os.path.exists(oracle_path):
        return 0
    with open(oracle_path, "rb") as f:
        data = f.read()
    acked = 0
    for line in data.split(b"\n"):
        if line.isdigit():
            acked = int(line)
    return acked


def verify_recovery(dbdir: str, oracle_path: str,
                    batches: int = DEFAULT_BATCHES,
                    batch_size: int = DEFAULT_BATCH_SIZE,
                    seed: int = DEFAULT_SEED) -> dict:
    """Recover the (possibly crashed) store and find the op-stream prefix
    it equals. Returns {"ok", "acked", "recovered_prefix", "n_edges"};
    raises AssertionError when no prefix >= acked matches."""
    from .core.disk import GraphDB

    acked = acked_ops(oracle_path)
    if not os.path.exists(os.path.join(dbdir, GraphDB.MANIFEST)):
        # the crash predates the store's creation — nothing was ever acked
        assert acked == 0, (
            f"{acked} ops acked but {dbdir} has no manifest")
        return {"ok": True, "acked": 0, "recovered_prefix": 0, "n_edges": 0}
    db = GraphDB.open(dbdir)
    try:
        s, d = db.to_coo()
        report = db.integrity_report()
    finally:
        db.tree.close()
    order = np.lexsort((d, s))
    got = (np.asarray(s)[order], np.asarray(d)[order])
    matches = [ops for ops, (rs, rd) in
               reference_states(batches, batch_size, seed)
               if got[0].shape == rs.shape
               and np.array_equal(got[0], rs) and np.array_equal(got[1], rd)]
    assert matches, (
        f"recovered state ({got[0].shape[0]} edges) matches NO op-stream "
        f"prefix (acked={acked}, report={report})")
    k = max(matches)
    assert k >= acked, (
        f"recovered prefix {k} < acked durable prefix {acked} — "
        f"acknowledged mutations were lost (report={report})")
    return {"ok": True, "acked": acked, "recovered_prefix": k,
            "n_edges": int(got[0].shape[0])}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name in ("run", "verify"):
        p = sub.add_parser(name)
        p.add_argument("dbdir")
        p.add_argument("--oracle", required=True)
        p.add_argument("--batches", type=int, default=DEFAULT_BATCHES)
        p.add_argument("--batch-size", type=int, default=DEFAULT_BATCH_SIZE)
        p.add_argument("--seed", type=int, default=DEFAULT_SEED)
    args = ap.parse_args(argv)
    if args.cmd == "run":
        run_workload(args.dbdir, args.oracle, batches=args.batches,
                     batch_size=args.batch_size, seed=args.seed)
        return 0
    result = verify_recovery(args.dbdir, args.oracle, batches=args.batches,
                             batch_size=args.batch_size, seed=args.seed)
    print(json.dumps(result))
    return 0


if __name__ == "__main__":
    sys.exit(main())
