"""`hypothesis` is an optional dev dependency: when it is installed the
property tests run for real; when it is missing they skip (instead of
erroring the whole module at collection, which used to take every other
test in the file down with it).

Usage in test modules:

    from _hypothesis_compat import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `hypothesis.strategies`: every attribute is a
        callable returning None — only ever consumed by the stub `given`."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def decorate(fn):
            # *args/**kwargs so pytest requests no fixtures and the wrapper
            # works both as a function and as a method.
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return decorate

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn

        return decorate
