"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch, run one forward/train step on CPU, assert output shapes and
no NaNs. The FULL configs are exercised by the dry-run (compile-only)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import bert4rec, transformer
from repro.models.gnn import equiformer_v2, gin, meshgraphnet, pna
from repro.optim import AdamWConfig, adamw_init, adamw_update

LM_ARCHS = ["granite-34b", "granite-3-2b", "qwen3-14b",
            "phi3.5-moe-42b-a6.6b", "qwen3-moe-235b-a22b"]
GNN_ARCHS = ["pna", "gin-tu", "equiformer-v2", "meshgraphnet"]


def test_registry_complete():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        spec = get_arch(a)
        assert spec.name == a
        assert len(spec.shapes) == 4, a
        assert spec.smoke_config is not None


def test_full_configs_match_assignment():
    """Exact published dims from the assignment."""
    c = get_arch("granite-34b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (88, 6144, 48, 1, 24576, 49152)
    c = get_arch("granite-3-2b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 2048, 32, 8, 8192, 49155)
    c = get_arch("qwen3-14b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 40, 8, 17408, 151936)
    assert c.qk_norm
    c = get_arch("phi3.5-moe-42b-a6.6b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab_size) == (32, 4096, 32, 8, 32064)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff_expert) == (16, 2, 6400)
    c = get_arch("qwen3-moe-235b-a22b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab_size) == (94, 4096, 64, 4, 151936)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff_expert) == (128, 8, 1536)
    assert c.qk_norm
    c = get_arch("pna").config
    assert (c.n_layers, c.d_hidden) == (4, 75)
    c = get_arch("gin-tu").config
    assert (c.n_layers, c.d_hidden) == (5, 64)
    c = get_arch("equiformer-v2").config
    assert (c.n_layers, c.d_hidden, c.l_max, c.m_max, c.n_heads) == (12, 128, 6, 2, 8)
    c = get_arch("meshgraphnet").config
    assert (c.n_layers, c.d_hidden, c.mlp_layers) == (15, 128, 2)
    c = get_arch("bert4rec").config
    assert (c.embed_dim, c.n_blocks, c.n_heads, c.seq_len) == (64, 2, 2, 200)


def test_long500k_skips_documented():
    for a in LM_ARCHS:
        cell = get_arch(a).shapes["long_500k"]
        assert cell.skip is not None and "full-attention" in cell.skip


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_arch(arch).smoke_config
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    opt = adamw_init(params)
    loss, grads = jax.value_and_grad(transformer.loss_fn)(params, batch, cfg)
    new_params, opt, metrics = adamw_update(grads, opt, params, AdamWConfig())
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, kv: a + float(jnp.abs(kv[0] - kv[1]).sum()),
        jax.tree.map(lambda a, b: (a, b), new_params, params), 0.0,
        is_leaf=lambda x: isinstance(x, tuple))
    assert delta > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch):
    cfg = get_arch(arch).smoke_config
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    logits, cache = transformer.prefill(params, toks, cfg, max_seq=12)
    assert logits.shape == (2, cfg.padded_vocab)
    lg, cache = transformer.decode_step(
        params, cache, toks[:, :1], jnp.int32(8), cfg)
    assert lg.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def _gnn_smoke_batch(key, arch, cfg, n=24, e=80):
    ks = jax.random.split(key, 6)
    b = {
        "src": jax.random.randint(ks[0], (e,), 0, n),
        "dst": jax.random.randint(ks[1], (e,), 0, n),
        "edge_mask": jnp.ones((e,), bool).at[-3:].set(False),
        "node_mask": jnp.ones((n,), bool),
    }
    if arch == "equiformer-v2":
        b["species"] = jax.random.randint(ks[2], (n,), 0, cfg.n_species)
        b["pos"] = jax.random.normal(ks[3], (n, 3))
    else:
        d_in = getattr(cfg, "d_in", None) or cfg.d_node_in
        b["x"] = jax.random.normal(ks[2], (n, d_in))
    if arch == "meshgraphnet":
        b["edge_attr"] = jax.random.normal(ks[4], (e, cfg.d_edge_in))
    return b


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    mod = {"pna": pna, "gin-tu": gin, "equiformer-v2": equiformer_v2,
           "meshgraphnet": meshgraphnet}[arch]
    cfg = get_arch(arch).smoke_config
    key = jax.random.PRNGKey(2)
    params = mod.init_params(key, cfg)
    b = _gnn_smoke_batch(key, arch, cfg)

    def loss(p):
        return (mod.forward(p, b, cfg) ** 2).mean()

    l, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))
    gn = jax.tree.reduce(lambda a, x: a + jnp.abs(x).sum(), g, 0.0)
    assert np.isfinite(float(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ["pna", "gin-tu"])
def test_gnn_chunked_matches_unchunked(arch):
    """edge_chunks is a pure execution-layout knob — results identical."""
    mod = {"pna": pna, "gin-tu": gin}[arch]
    cfg = get_arch(arch).smoke_config
    key = jax.random.PRNGKey(3)
    params = mod.init_params(key, cfg)
    b = _gnn_smoke_batch(key, arch, cfg, n=24, e=80)
    out1 = mod.forward(params, b, cfg)
    cfg2 = dataclasses.replace(cfg, edge_chunks=4)
    out2 = mod.forward(params, b, cfg2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-5, atol=2e-5)


def test_meshgraphnet_chunked_matches_unchunked():
    cfg = get_arch("meshgraphnet").smoke_config
    key = jax.random.PRNGKey(4)
    params = meshgraphnet.init_params(key, cfg)
    b = _gnn_smoke_batch(key, "meshgraphnet", cfg, n=24, e=80)
    out1 = meshgraphnet.forward(params, b, cfg)
    cfg2 = dataclasses.replace(cfg, edge_chunks=4)
    out2 = meshgraphnet.forward(params, b, cfg2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-5, atol=2e-5)


def test_equiformer_chunked_matches_unchunked():
    cfg = get_arch("equiformer-v2").smoke_config
    key = jax.random.PRNGKey(5)
    params = equiformer_v2.init_params(key, cfg)
    b = _gnn_smoke_batch(key, "equiformer-v2", cfg, n=24, e=80)
    out1 = equiformer_v2.forward(params, b, cfg)
    cfg2 = dataclasses.replace(cfg, edge_chunks=4)
    out2 = equiformer_v2.forward(params, b, cfg2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-4)


def test_bert4rec_smoke_train_step():
    cfg = get_arch("bert4rec").smoke_config
    key = jax.random.PRNGKey(6)
    params = bert4rec.init_params(key, cfg)
    seq = jax.random.randint(key, (4, cfg.seq_len), 1, cfg.n_items + 1)
    mpos = jnp.full((4, 1), 3, jnp.int32)
    labels = seq[:, 3:4]
    seq = seq.at[:, 3].set(cfg.vocab - 1)
    opt = adamw_init(params)
    loss, grads = jax.value_and_grad(bert4rec.masked_lm_loss)(
        params, {"item_seq": seq, "masked_positions": mpos,
                 "labels": labels}, cfg)
    p2, opt, _ = adamw_update(grads, opt, params, AdamWConfig())
    assert np.isfinite(float(loss))
    scores = bert4rec.score_all_items(params, seq, cfg)
    assert scores.shape == (4, cfg.padded_vocab)
    assert np.isfinite(np.asarray(scores)).all()
