"""Codec tests: bit-parallel Elias-Gamma vs the reference loops (bitwise),
round-trip properties, and the resident index structures (paper §4.2.1)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.codec import (
    BlockedGammaPointer,
    GammaChunkedIndex,
    SparseIndex,
    decode_monotonic,
    decode_monotonic_blocked,
    elias_gamma_decode,
    elias_gamma_decode_ref,
    elias_gamma_encode,
    elias_gamma_encode_ref,
    encode_monotonic,
    encode_monotonic_blocked,
)


class TestBitwiseIdentity:
    """The vectorized codec must produce the exact bytes (and read the exact
    values) of the original per-value/per-bit loops."""

    def test_encode_identical_small(self):
        rng = np.random.default_rng(0)
        for _ in range(50):
            vals = rng.integers(1, 1 << int(rng.integers(1, 40)),
                                int(rng.integers(1, 120)))
            p1, b1 = elias_gamma_encode(vals)
            p2, b2 = elias_gamma_encode_ref(vals)
            assert b1 == b2
            assert np.array_equal(p1, p2)

    def test_decode_identical(self):
        rng = np.random.default_rng(1)
        for _ in range(50):
            vals = rng.integers(1, 1 << 20, int(rng.integers(1, 120)))
            packed, nbits = elias_gamma_encode(vals)
            assert np.array_equal(elias_gamma_decode(packed, nbits),
                                  elias_gamma_decode_ref(packed, nbits))

    def test_blocked_stream_identical_to_plain(self):
        rng = np.random.default_rng(2)
        seq = np.sort(rng.integers(0, 1 << 45, 3000))
        pk_b, nb_b, f_b, _ = encode_monotonic_blocked(seq)
        pk, nb, f = encode_monotonic(seq)
        assert (nb_b, f_b) == (nb, f)
        assert np.array_equal(pk_b, pk)


class TestRoundTrips:
    def test_gamma_roundtrip_edge_cases(self):
        for vals in ([1], [1, 1, 1], [2 ** 40], list(range(1, 300))):
            vals = np.asarray(vals, np.int64)
            packed, nbits = elias_gamma_encode(vals)
            assert np.array_equal(elias_gamma_decode(packed, nbits), vals)

    def test_gamma_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            elias_gamma_encode(np.asarray([0]))

    def test_monotonic_roundtrip(self):
        rng = np.random.default_rng(3)
        for n in (0, 1, 2, 63, 64, 65, 1000):
            seq = np.sort(rng.integers(0, 1 << 30, n))
            packed, nbits, first = encode_monotonic(seq)
            assert np.array_equal(decode_monotonic(packed, nbits, first, n), seq)

    def test_blocked_roundtrip(self):
        rng = np.random.default_rng(4)
        for n in (0, 1, 2, 63, 64, 65, 128, 129, 1000):
            seq = np.sort(rng.integers(0, 1 << 50, n))
            packed, nbits, first, offs = encode_monotonic_blocked(seq)
            out = decode_monotonic_blocked(packed, nbits, first, n, offs)
            assert np.array_equal(out, seq), n

    def test_blocked_roundtrip_constant_and_huge(self):
        for seq in ([0] * 200, [5] * 64, [0, 2 ** 61], list(range(0, 10**7, 10**4))):
            seq = np.asarray(seq, np.int64)
            packed, nbits, first, offs = encode_monotonic_blocked(seq)
            out = decode_monotonic_blocked(packed, nbits, first, len(seq), offs)
            assert np.array_equal(out, seq)


@given(st.lists(st.integers(1, 2 ** 45), min_size=1, max_size=300),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_property_gamma_bitwise_and_roundtrip(vals, _seed):
    vals = np.asarray(vals, np.int64)
    p1, b1 = elias_gamma_encode(vals)
    p2, b2 = elias_gamma_encode_ref(vals)
    assert b1 == b2 and np.array_equal(p1, p2)
    assert np.array_equal(elias_gamma_decode(p1, b1), vals)
    assert np.array_equal(elias_gamma_decode_ref(p1, b1), vals)


@given(st.lists(st.integers(0, 2 ** 50), min_size=0, max_size=400))
@settings(max_examples=40, deadline=None)
def test_property_blocked_monotonic_roundtrip(raw):
    seq = np.sort(np.asarray(raw, np.int64))
    packed, nbits, first, offs = encode_monotonic_blocked(seq)
    out = decode_monotonic_blocked(packed, nbits, first, len(seq), offs)
    assert np.array_equal(out, seq)


class TestResidentIndexes:
    def _keys(self, n=5000, seed=5):
        rng = np.random.default_rng(seed)
        return np.unique(rng.integers(0, 10 ** 8, n))

    def test_sparse_index_vs_linear_scan(self):
        keys = self._keys()
        idx = SparseIndex(keys, stride=64)
        rng = np.random.default_rng(6)
        probes = np.concatenate([keys[:: 37], rng.integers(0, 10 ** 8, 200)])
        for k in probes:
            hits = np.nonzero(keys == k)[0]
            expect = int(hits[0]) if hits.size else -1
            assert idx.lookup(int(k)) == expect
        assert idx.block_reads == probes.shape[0]

    def test_gamma_chunked_index_vs_linear_scan(self):
        keys = self._keys()
        idx = GammaChunkedIndex(keys, chunk=256)
        rng = np.random.default_rng(7)
        probes = np.concatenate([keys[:: 41], rng.integers(0, 10 ** 8, 200)])
        for k in probes:
            hits = np.nonzero(keys == k)[0]
            expect = int(hits[0]) if hits.size else -1
            assert idx.lookup(int(k)) == expect
        assert np.array_equal(idx.decode_all(), keys)
        # the whole point: compressed residency
        assert idx.nbytes() < keys.nbytes

    def test_gamma_chunked_empty(self):
        idx = GammaChunkedIndex(np.empty(0, np.int64))
        assert idx.lookup(5) == -1
        assert idx.decode_all().size == 0


class TestBlockedGammaPointer:
    def test_searchsorted_and_values_match_numpy(self):
        rng = np.random.default_rng(8)
        for _ in range(30):
            arr = np.unique(rng.integers(0, 1 << 40, int(rng.integers(0, 2000))))
            bp = BlockedGammaPointer.from_array(arr)
            assert np.array_equal(bp.decode_all(), arr)
            keys = (np.concatenate([arr[::5], rng.integers(0, arr.max() + 2, 40)])
                    if arr.size else np.asarray([0, 5], np.int64))
            assert np.array_equal(bp.searchsorted(keys),
                                  np.searchsorted(arr, keys))
            if arr.size:
                idx = rng.integers(0, arr.size, 30)
                assert np.array_equal(bp.values_at(idx), arr[idx])

    def test_values_at_nondecreasing_with_duplicates(self):
        rng = np.random.default_rng(9)
        arr = np.sort(rng.integers(0, 50, 700))  # ptr-array shape: many dups
        bp = BlockedGammaPointer.from_array(arr)
        idx = rng.integers(0, arr.size, 100)
        assert np.array_equal(bp.values_at(idx), arr[idx])
        assert np.array_equal(bp.decode_all(), arr)

    def test_compressed_residency(self):
        arr = np.cumsum(np.random.default_rng(10).integers(1, 30, 50_000))
        bp = BlockedGammaPointer.from_array(arr)
        assert bp.nbytes() < arr.nbytes / 2

    def test_block_boundary_sizes(self):
        """Regression: n = k*64 + 1 gives a final value block with ZERO
        deltas and no directory entry — lookups must not index past the
        directory."""
        rng = np.random.default_rng(11)
        for n in (64, 65, 128, 129, 4993, 5120, 5121):
            arr = np.cumsum(rng.integers(1, 9, n))
            bp = BlockedGammaPointer.from_array(arr)
            keys = np.concatenate([arr[-3:], [arr[-1] + 5], arr[:3]])
            assert np.array_equal(bp.searchsorted(keys),
                                  np.searchsorted(arr, keys)), n
            assert np.array_equal(bp.values_at(np.asarray([0, n - 1])),
                                  arr[[0, n - 1]]), n
            assert np.array_equal(bp.decode_all(), arr), n


@given(st.lists(st.integers(0, 2 ** 40), min_size=1, max_size=400),
       st.lists(st.integers(0, 2 ** 40), min_size=1, max_size=20))
@settings(max_examples=40, deadline=None)
def test_property_blocked_pointer_vs_numpy(raw, probes):
    arr = np.unique(np.asarray(raw, np.int64))
    bp = BlockedGammaPointer.from_array(arr)
    keys = np.asarray(probes, np.int64)
    assert np.array_equal(bp.searchsorted(keys), np.searchsorted(arr, keys))
    assert np.array_equal(bp.decode_all(), arr)


@given(st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=500),
       st.integers(0, 10 ** 6))
@settings(max_examples=40, deadline=None)
def test_property_sparse_and_gamma_lookup_agree_with_scan(raw, probe):
    keys = np.unique(np.asarray(raw, np.int64))
    sparse = SparseIndex(keys, stride=16)
    gamma = GammaChunkedIndex(keys, chunk=32)
    hits = np.nonzero(keys == probe)[0]
    expect = int(hits[0]) if hits.size else -1
    assert sparse.lookup(probe) == expect
    assert gamma.lookup(probe) == expect
