"""Disk tier tests: partition files, DiskPartition queries, GraphDB
open/close/reopen, crash recovery, eviction, block-read accounting, and
out-of-core PSW streaming (ISSUE 3)."""
import json
import os
import shutil

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    GraphDB,
    GraphPAL,
    IntervalMap,
    LSMTree,
    build_partition,
    open_partition_file,
    partition_digest,
    write_partition_file,
)
from repro.core.disk import DiskPartition, IOStats, RawDiskIndex, SparseDiskIndex
from repro.core.psw import pagerank_out_of_core, stream_interval_buckets


def random_partition(rng, n_edges=5000, n_src=2000, interval=(0, 500),
                     with_cols=True):
    src = rng.integers(0, n_src, n_edges)
    dst = rng.integers(interval[0], interval[1], n_edges)
    cols = {}
    if with_cols:
        cols["w"] = rng.random(n_edges).astype(np.float32)
        cols["t"] = rng.integers(0, 100, n_edges).astype(np.int32)
    return build_partition(interval, src, dst, columns=cols)


def make_db(tmp_path, name="db", **kw):
    opts = dict(max_id=9999, n_partitions=16, n_levels=3, branching=4,
                buffer_cap=2000, max_partition_edges=8000,
                persist_min_edges=512)
    opts.update(kw)
    return GraphDB.create(str(tmp_path / name), **opts)


class TestPartitionFile:
    def test_save_mmap_load_equality(self, tmp_path):
        rng = np.random.default_rng(0)
        part = random_partition(rng)
        path = str(tmp_path / "p.pal")
        write_partition_file(path, part)
        dp = open_partition_file(path)
        assert dp.n_edges == part.n_edges
        assert dp.interval == part.interval
        for name in ("src", "dst", "etype", "dst_perm"):
            assert np.array_equal(np.asarray(getattr(dp, name)),
                                  getattr(part, name)), name
        for name in ("src_vertices", "src_ptr", "dst_vertices", "dst_ptr"):
            got = getattr(dp, name)
            assert got.dtype == np.int64
            assert np.array_equal(got, getattr(part, name)), name
        for k in part.columns:
            assert np.array_equal(np.asarray(dp.columns[k]), part.columns[k])
            assert dp.columns[k].dtype == part.columns[k].dtype

    def test_query_equality_after_mmap(self, tmp_path):
        rng = np.random.default_rng(1)
        part = random_partition(rng)
        path = str(tmp_path / "p.pal")
        write_partition_file(path, part)
        dp = open_partition_file(path)
        for v in range(0, 2000, 53):
            assert np.array_equal(dp.out_edges(v), part.out_edges(v))
        for v in range(0, 500, 13):
            assert np.array_equal(dp.in_edges(v), part.in_edges(v))
        a, b = part.window((100, 300))
        assert dp.window((100, 300)) == (a, b)

    def test_raw_index_mode_matches_gamma(self, tmp_path):
        rng = np.random.default_rng(2)
        part = random_partition(rng)
        path = str(tmp_path / "p.pal")
        write_partition_file(path, part)
        g = open_partition_file(path, index_mode="gamma")
        r = open_partition_file(path, index_mode="raw")
        for name in ("src_vertices", "src_ptr", "dst_vertices", "dst_ptr"):
            assert np.array_equal(np.asarray(getattr(r, name)),
                                  getattr(g, name))

    def test_evict_then_requery(self, tmp_path):
        rng = np.random.default_rng(3)
        part = random_partition(rng)
        path = str(tmp_path / "p.pal")
        write_partition_file(path, part)
        dp = open_partition_file(path)
        before = np.array(dp.out_edges(7))
        # scalar/batched queries use the chunked path and cache NOTHING;
        # only explicit full-array access materializes a decoded cache
        assert dp.cached_nbytes() == 0
        _ = dp.src_vertices
        assert dp.cached_nbytes() > 0
        dp.evict()
        assert dp.cached_nbytes() == 0
        assert np.array_equal(dp.out_edges(7), before)
        assert dp.resident_nbytes() > 0  # pinned blobs survive

    def test_copy_on_write_mutations_mark_dirty(self, tmp_path):
        rng = np.random.default_rng(4)
        part = random_partition(rng)
        path = str(tmp_path / "p.pal")
        write_partition_file(path, part)
        dp = open_partition_file(path)
        assert not dp.dirty
        dp.set_column("w", 3, 9.5)
        assert dp.dirty
        assert float(dp.columns["w"][3]) == 9.5
        dp2 = open_partition_file(path)
        assert float(dp2.columns["w"][3]) != 9.5  # file untouched
        dp2.set_etype([1], 4)
        assert dp2.dirty and int(dp2.etype[1]) == 4
        dp3 = open_partition_file(path)
        dp3.tombstone([0])
        # tombstones do NOT dirty the file — they live in a sidecar, so the
        # content-addressed file stays linkable/dedupable
        assert not dp3.dirty
        assert 0 not in dp3.out_edges(int(dp3.edge_at(0)[0]))

    def test_digest_content_addressing(self, tmp_path):
        rng = np.random.default_rng(5)
        part = random_partition(rng)
        path = str(tmp_path / "p.pal")
        write_partition_file(path, part)
        dp = open_partition_file(path)
        assert partition_digest(dp) == partition_digest(part)

    def test_empty_partition_roundtrip(self, tmp_path):
        part = build_partition((0, 10), np.empty(0, np.int64),
                               np.empty(0, np.int64))
        path = str(tmp_path / "e.pal")
        write_partition_file(path, part)
        dp = open_partition_file(path)
        assert dp.n_edges == 0
        assert dp.out_edges(3).size == 0

    def test_bad_magic_rejected(self, tmp_path):
        path = str(tmp_path / "bad.pal")
        with open(path, "wb") as f:
            f.write(b"NOTAPART" + b"\0" * 64)
        with pytest.raises(ValueError):
            open_partition_file(path)


@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 3000))
@settings(max_examples=15, deadline=None)
def test_property_partition_file_roundtrip(seed, n_edges):
    """save → mmap-load → every query agrees with the in-RAM partition."""
    import tempfile
    rng = np.random.default_rng(seed)
    part = random_partition(rng, n_edges=n_edges, n_src=300, interval=(0, 200))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.pal")
        write_partition_file(path, part)
        dp = open_partition_file(path)
        for name in ("src_vertices", "src_ptr", "dst_vertices", "dst_ptr"):
            assert np.array_equal(getattr(dp, name), getattr(part, name))
        for v in rng.integers(0, 300, 10):
            assert np.array_equal(dp.out_edges(int(v)), part.out_edges(int(v)))
        for v in rng.integers(0, 200, 10):
            assert np.array_equal(dp.in_edges(int(v)), part.in_edges(int(v)))


class TestGraphDB:
    def _fill(self, db, n=40000, seed=10, max_id=10000):
        rng = np.random.default_rng(seed)
        src = rng.integers(0, max_id, n)
        dst = rng.integers(0, max_id, n)
        db.insert_edges(src, dst)
        return src, dst

    def test_insert_query_with_disk_partitions(self, tmp_path):
        db = make_db(tmp_path)
        src, dst = self._fill(db)
        assert len(db._disk_partitions()) > 0, "nothing was flushed to disk"
        for v in np.unique(src)[:15]:
            assert np.array_equal(np.sort(db.out_neighbors(int(v))),
                                  np.sort(dst[src == v]))
        for v in np.unique(dst)[:15]:
            assert np.array_equal(np.sort(db.in_neighbors(int(v))),
                                  np.sort(src[dst == v]))

    def test_close_reopen_bitwise(self, tmp_path):
        db = make_db(tmp_path)
        src, dst = self._fill(db)
        sample = [int(v) for v in np.unique(src)[:25]]
        pre_out = {v: db.out_neighbors(v).tolist() for v in sample}
        pre_coo = sorted(zip(*map(list, db.to_coo())))
        db.close()
        db2 = GraphDB.open(str(tmp_path / "db"))
        assert sorted(zip(*map(list, db2.to_coo()))) == pre_coo
        for v in sample:
            assert db2.out_neighbors(v).tolist() == pre_out[v]

    def test_crash_recovery_wal_tail(self, tmp_path):
        db = make_db(tmp_path)
        src, dst = self._fill(db)
        db.checkpoint()
        rng = np.random.default_rng(11)
        s2 = rng.integers(0, 10000, 5000)
        d2 = rng.integers(0, 10000, 5000)
        db.insert_edges(s2, d2)
        pre = sorted(zip(*map(list, db.to_coo())))
        db.tree.wal_flush()
        # simulated kill: copy the directory while the DB is still "live"
        crash = str(tmp_path / "crash")
        shutil.copytree(str(tmp_path / "db"), crash)
        db2 = GraphDB.open(crash)
        assert sorted(zip(*map(list, db2.to_coo()))) == pre

    def test_kill_between_manifest_writes(self, tmp_path):
        """A crash after the tmp manifest is written but before the atomic
        rename must leave the PREVIOUS manifest fully restorable."""
        db = make_db(tmp_path)
        src, dst = self._fill(db, n=20000)
        db.checkpoint()
        pre = sorted(zip(*map(list, db.to_coo())))
        # half-written next manifest: garbage tmp file next to the real one
        with open(str(tmp_path / "db" / (GraphDB.MANIFEST + ".tmp")), "w") as f:
            f.write('{"config": TRUNCATED')
        db.tree.wal_flush()
        crash = str(tmp_path / "crash")
        shutil.copytree(str(tmp_path / "db"), crash)
        db2 = GraphDB.open(crash)
        assert sorted(zip(*map(list, db2.to_coo()))) == pre

    def test_torn_wal_record_dropped(self, tmp_path):
        """A crash mid-append leaves a torn trailing record in the active
        WAL segment; replay must drop it and recovery must still open."""
        db = make_db(tmp_path)
        src, dst = self._fill(db, n=5000)
        db.tree.wal_flush()
        pre = sorted(zip(*map(list, db.to_coo())))
        segs = db.tree.wal.segments()
        with open(segs[-1][2], "ab") as f:  # torn trailing record
            f.write(b"\x01\x02\x03")
        crash = str(tmp_path / "crash")
        shutil.copytree(str(tmp_path / "db"), crash)
        db2 = GraphDB.open(crash)
        assert sorted(zip(*map(list, db2.to_coo()))) == pre

    def test_checkpoint_gcs_unreferenced_files(self, tmp_path):
        db = make_db(tmp_path)
        self._fill(db, n=30000)
        parts_dir = str(tmp_path / "db" / "parts")
        before_files = set(os.listdir(parts_dir))
        db.checkpoint()
        manifest = db._read_manifest()
        live = {f"part_{e['digest']}.pal" for lv in manifest["levels"]
                for e in lv if e}
        on_disk = {f for f in os.listdir(parts_dir) if f.endswith(".pal")}
        assert on_disk == live
        # every live digest is openable
        for e in (e for lv in manifest["levels"] for e in lv if e):
            db.store.open(e["digest"])

    def test_legacy_wal_log_migrates_on_open(self, tmp_path):
        """A PR-3-format database (single wal.log, manifest wal_offset in
        its bytes) must not lose its WAL tail: open replays the legacy
        records, re-logs them into the segmented WAL, and retires the
        file."""
        import struct
        db = make_db(tmp_path)
        src, dst = self._fill(db, n=20000)
        db.checkpoint()
        db.close()
        dbdir = str(tmp_path / "db")
        # forge the legacy layout: drop the segmented WAL, put the
        # post-checkpoint tail into wal.log, point the manifest at byte 0
        shutil.rmtree(os.path.join(dbdir, "wal"))
        iv = db.tree.intervals
        extra = [(9001, 42), (9002, 43)]
        with open(os.path.join(dbdir, "wal.log"), "wb") as f:
            for s, d in extra:
                f.write(struct.pack("<qqb", iv.to_internal_scalar(s),
                                    iv.to_internal_scalar(d), 0))
        with open(os.path.join(dbdir, GraphDB.MANIFEST)) as f:
            manifest = json.load(f)
        manifest["wal_offset"] = 0
        with open(os.path.join(dbdir, GraphDB.MANIFEST), "w") as f:
            json.dump(manifest, f)
        db2 = GraphDB.open(dbdir)
        assert db2.n_edges == 20000 + 2
        assert 42 in db2.out_neighbors(9001)
        assert not os.path.exists(os.path.join(dbdir, "wal.log"))
        assert os.path.exists(os.path.join(dbdir, "wal.log.migrated"))
        # the migrated records are durable in the NEW wal/manifest
        db2.close()
        db3 = GraphDB.open(dbdir)
        assert 43 in db3.out_neighbors(9002)

    def test_create_refuses_existing(self, tmp_path):
        make_db(tmp_path)
        with pytest.raises(FileExistsError):
            make_db(tmp_path)

    def test_deletes_survive_checkpoint(self, tmp_path):
        db = make_db(tmp_path)
        src, dst = self._fill(db, n=20000)
        v, w = int(src[0]), int(dst[0])
        assert db.delete_edge(v, w)
        db.checkpoint()
        db.close()
        db2 = GraphDB.open(str(tmp_path / "db"))
        assert w not in db2.out_neighbors(v)

    def test_engine_block_read_accounting(self, tmp_path):
        db = make_db(tmp_path)
        src, dst = self._fill(db)
        eng = db.storage_engine()
        assert db.io.block_reads == 0
        vals, offsets = eng.out_neighbors_batch(
            [int(v) for v in np.unique(src)[:50]])
        assert db.io.block_reads > 0
        assert db.io.bytes_read > 0

    def test_eviction_bounds_cache(self, tmp_path):
        db = make_db(tmp_path, resident_budget_bytes=1)
        src, dst = self._fill(db)
        # a query materializes decoded indexes...
        db.storage_engine().out_neighbors_batch([int(src[0])])
        # ...and the next sink call evicts them back under budget
        rng = np.random.default_rng(12)
        db.insert_edges(rng.integers(0, 10000, 10000),
                        rng.integers(0, 10000, 10000))
        db.evict()
        assert sum(p.cached_nbytes() for p in db._disk_partitions()) == 0
        # queries still work after eviction
        assert db.out_neighbors(int(src[0])).size >= 0

    def test_lru_eviction_keeps_recently_touched(self, tmp_path):
        """Page-cache-aware eviction (ISSUE 4 satellite): over budget, the
        COLDEST partitions give up their decoded caches first; one the
        engine just touched survives if dropping the cold set suffices."""
        db = make_db(tmp_path)
        src, dst = self._fill(db)
        parts = db._disk_partitions()
        assert len(parts) >= 2
        for p in parts:  # materialize a decoded cache everywhere
            _ = p.src_vertices
        # touch one partition recently, leave the rest cold
        hot = parts[0]
        db._touch(hot)
        db.resident_budget_bytes = hot.cached_nbytes()
        db.maybe_evict()
        assert hot.cached_nbytes() > 0, "hot partition was evicted"
        assert sum(p.cached_nbytes() for p in parts if p is not hot) == 0
        # shrinking the budget below the hot set evicts it too
        db.resident_budget_bytes = 0
        db.maybe_evict()
        assert hot.cached_nbytes() == 0

    def test_advise_dontneed_is_safe(self, tmp_path):
        """madvise(DONTNEED) on mapped sections is advisory: queries after
        the hint return identical results (pages fault back in)."""
        db = make_db(tmp_path)
        src, dst = self._fill(db)
        part = db._disk_partitions()[0]
        v = int(part.src[0])
        before = np.array(part.out_edges(v))
        part.advise_dontneed()
        assert np.array_equal(part.out_edges(v), before)

    def test_update_column_on_disk_partition(self, tmp_path):
        db = make_db(tmp_path, column_dtypes={"w": np.float32})
        rng = np.random.default_rng(13)
        src = rng.integers(0, 10000, 20000)
        dst = rng.integers(0, 10000, 20000)
        db.insert_edges(src, dst, columns={"w": np.ones(20000, np.float32)})
        db.flush_all()
        assert db.update_edge_column(int(src[0]), int(dst[0]), "w", 7.5)
        db.close()
        db2 = GraphDB.open(str(tmp_path / "db"))
        eng = db2.storage_engine()
        batch = eng.edge_columns_batch([int(src[0])], names=["w"])
        hit = np.nonzero(batch.dst == int(dst[0]))[0]
        assert (batch.columns["w"][hit] == 7.5).any()


class TestOutOfCorePSW:
    def _db(self, tmp_path, n=25000):
        db = make_db(tmp_path, max_id=2000 - 1, n_partitions=16,
                     buffer_cap=1500, max_partition_edges=4000,
                     persist_min_edges=256)
        rng = np.random.default_rng(20)
        src = rng.integers(0, 2000, n)
        dst = rng.integers(0, 2000, n)
        db.insert_edges(src, dst)
        return db, src, dst

    def test_buckets_bit_identical_to_device_graph(self, tmp_path):
        from repro.core.psw import build_device_graph
        db, src, dst = self._db(tmp_path)
        dg = build_device_graph(db.tree, with_window_plan=False)
        S = np.asarray(dg.src)
        D = np.asarray(dg.dst_local)
        M = np.asarray(dg.mask)
        L = dg.interval_len
        total = 0
        for i, s, d in stream_interval_buckets(db.tree, evict_each=True):
            n = s.shape[0]
            total += n
            assert np.array_equal(S[i][:n], s.astype(np.int32))
            assert np.array_equal(D[i][:n], (d - i * L).astype(np.int32))
            assert M[i][:n].all() and not M[i][n:].any()
        assert total == dg.n_edges

    def test_pagerank_out_of_core_matches_device(self, tmp_path):
        from repro.core.psw import build_device_graph, pagerank_device
        db, src, dst = self._db(tmp_path)
        pr_dev = np.asarray(pagerank_device(
            build_device_graph(db.tree), n_iters=3,
            mode="dense_gather")).ravel()
        pr_ooc = pagerank_out_of_core(db.tree, n_iters=3)
        np.testing.assert_allclose(pr_ooc, pr_dev, rtol=1e-4, atol=1e-4)

    def test_streaming_works_on_pal_and_lsm(self):
        rng = np.random.default_rng(21)
        src = rng.integers(0, 1000, 8000)
        dst = rng.integers(0, 1000, 8000)
        pal = GraphPAL.from_edges(src, dst, n_partitions=8, max_id=999)
        iv = IntervalMap.for_capacity(999, 8)
        lsm = LSMTree(iv, n_levels=2, branching=8, buffer_cap=1000,
                      max_partition_edges=3000)
        lsm.insert_edges(src, dst)
        buckets_pal = [s for _, s, _ in stream_interval_buckets(pal)]
        buckets_lsm = [s for _, s, _ in stream_interval_buckets(lsm)]
        for a, b in zip(buckets_pal, buckets_lsm):
            assert np.array_equal(a, b)


class TestCheckpointLinks:
    def test_save_lsm_hard_links_disk_partitions(self, tmp_path):
        from repro.checkpoint.manager import restore_lsm, save_lsm
        db = make_db(tmp_path)
        rng = np.random.default_rng(40)
        src = rng.integers(0, 10000, 30000)
        dst = rng.integers(0, 10000, 30000)
        db.insert_edges(src, dst)
        db.checkpoint()
        ck = str(tmp_path / "ckpt")
        m = save_lsm(db, ck)
        assert m["linked"] > 0 and m["written"] <= 1  # only the empty npz
        linked = [f for f in os.listdir(ck) if f.endswith(".pal")]
        assert os.stat(os.path.join(ck, linked[0])).st_nlink >= 2
        ref = sorted(zip(*map(list, db.to_coo())))
        t2 = restore_lsm(ck)
        assert sorted(zip(*map(list, t2.to_coo()))) == ref
        # the checkpoint must survive store GC (links keep inodes alive)
        db.store.gc(set())
        t3 = restore_lsm(ck)
        assert sorted(zip(*map(list, t3.to_coo()))) == ref

    def test_save_lsm_links_tombstoned_partition_with_dead_sidecar(self, tmp_path):
        """A tombstoned disk partition must still take the hard-link path
        (dead lives in a sidecar, the file is content-clean) and restore
        with the tombstone applied."""
        from repro.checkpoint.manager import restore_lsm, save_lsm
        db = make_db(tmp_path)
        rng = np.random.default_rng(41)
        src = rng.integers(0, 10000, 30000)
        dst = rng.integers(0, 10000, 30000)
        db.insert_edges(src, dst)
        db.checkpoint()
        v, w = int(src[0]), int(dst[0])
        assert db.delete_edge(v, w)
        ck = str(tmp_path / "ckpt")
        m = save_lsm(db, ck)
        assert m["written"] <= 1  # tombstoned partitions still link
        assert any(f.endswith(".dead.npy") for f in os.listdir(ck))
        t2 = restore_lsm(ck)
        assert sorted(zip(*map(list, t2.to_coo()))) == \
            sorted(zip(*map(list, db.to_coo())))

    def test_tombstone_durable_at_checkpoint_reopen(self, tmp_path):
        db = make_db(tmp_path)
        rng = np.random.default_rng(42)
        src = rng.integers(0, 10000, 30000)
        dst = rng.integers(0, 10000, 30000)
        db.insert_edges(src, dst)
        db.checkpoint()  # all on disk, clean
        v, w = int(src[0]), int(dst[0])
        assert db.delete_edge(v, w)
        db.checkpoint()  # clean partition + new tombstone → sidecar only
        db.close()
        db2 = GraphDB.open(str(tmp_path / "db"))
        assert w not in db2.out_neighbors(v)


class TestFigure8Readers:
    def test_raw_and_sparse_disk_index(self, tmp_path):
        rng = np.random.default_rng(30)
        part = random_partition(rng, n_edges=20000, n_src=8000,
                                with_cols=False)
        path = str(tmp_path / "p.pal")
        write_partition_file(path, part)
        dp = open_partition_file(path)
        off, dt, n = dp._section_spec("src_vertices_raw")
        raw = RawDiskIndex(path, off, n)
        sparse = SparseDiskIndex(path, off, n, stride=128)
        keys = part.src_vertices
        probes = np.concatenate([keys[::97], rng.integers(0, 8000, 50)])
        for k in probes:
            hits = np.nonzero(keys == int(k))[0]
            expect = int(hits[0]) if hits.size else -1
            assert raw.lookup(int(k)) == expect
            assert sparse.lookup(int(k)) == expect
        assert raw.block_reads > probes.shape[0]      # log-blocks per probe
        # sparse: exactly one data block per probe
        assert sparse.block_reads - raw.block_reads == probes.shape[0] \
            or sparse.block_reads >= probes.shape[0]
        raw.close()
        sparse.close()


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_property_db_equals_reference_after_reopen(seed):
    """Arbitrary insert batches → close → reopen: queries equal a dense
    reference edge list."""
    import tempfile
    rng = np.random.default_rng(seed)
    n = int(rng.integers(500, 4000))
    src = rng.integers(0, 3000, n)
    dst = rng.integers(0, 3000, n)
    with tempfile.TemporaryDirectory() as d:
        db = GraphDB.create(os.path.join(d, "db"), max_id=2999,
                            n_partitions=16, n_levels=3, branching=4,
                            buffer_cap=300, max_partition_edges=1200,
                            persist_min_edges=128)
        k = n // 2
        db.insert_edges(src[:k], dst[:k])
        db.insert_edges(src[k:], dst[k:])
        db.close()
        db2 = GraphDB.open(os.path.join(d, "db"))
        assert db2.n_edges == n
        for v in np.unique(src)[:5]:
            assert np.array_equal(np.sort(db2.out_neighbors(int(v))),
                                  np.sort(dst[src == v]))
        for v in np.unique(dst)[:5]:
            assert np.array_equal(np.sort(db2.in_neighbors(int(v))),
                                  np.sort(src[dst == v]))
