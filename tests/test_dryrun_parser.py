"""Unit tests for the HLO collective parser (roofline input): shape-byte
accounting and while-loop trip-count multiplication."""
from repro.launch.dryrun import parse_collective_bytes

FLAT_HLO = """
HloModule test

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256]{1,0} parameter(0)
  %ag = f32[256,256]{1,0} all-gather(%a), dimensions={0}
  %ar = bf16[128,256]{1,0} all-reduce(%a), to_apply=%add
  ROOT %r = f32[128,256]{1,0} add(%a, %a)
}
"""

LOOPED_HLO = """
HloModule test

%cond.1 (s: (s32[], f32[64])) -> pred[] {
  %s = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body.2 (s: (s32[], f32[64])) -> (s32[], f32[64]) {
  %s = (s32[], f32[64]) parameter(0)
  %x = f32[64]{0} get-tuple-element(%s), index=1
  %ar = f32[64]{0} all-reduce(%x), to_apply=%add
  ROOT %t = (s32[], f32[64]) tuple(%i2, %ar)
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  %w = (s32[], f32[64]) while(%init), condition=%cond.1, body=%body.2
  %ag = f32[128]{0} all-gather(%a), dimensions={0}
  ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
}
"""


def test_flat_collective_bytes():
    total, by_kind, counts, n_whiles = parse_collective_bytes(FLAT_HLO)
    assert by_kind["all-gather"] == 256 * 256 * 4
    assert by_kind["all-reduce"] == 128 * 256 * 2   # bf16
    assert counts == {"all-gather": 1, "all-reduce": 1}
    assert n_whiles == 0
    assert total == 256 * 256 * 4 + 128 * 256 * 2


def test_while_trip_count_multiplication():
    total, by_kind, counts, n_whiles = parse_collective_bytes(LOOPED_HLO)
    assert n_whiles == 1
    # the in-loop all-reduce executes 7 times
    assert by_kind["all-reduce"] == 7 * 64 * 4
    assert counts["all-reduce"] == 7
    # the entry-level all-gather executes once
    assert by_kind["all-gather"] == 128 * 4
    assert total == 7 * 64 * 4 + 128 * 4


def test_async_done_not_double_counted():
    hlo = """
ENTRY %main (a: f32[32]) -> f32[32] {
  %a = f32[32]{0} parameter(0)
  %s = f32[64]{0} all-gather-start(%a), dimensions={0}
  %d = f32[64]{0} all-gather-done(%s)
  ROOT %r = f32[32]{0} slice(%d), slice={[0:32]}
}
"""
    total, by_kind, counts, _ = parse_collective_bytes(hlo)
    assert counts.get("all-gather", 0) == 1
    assert by_kind["all-gather"] == 64 * 4
