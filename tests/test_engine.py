"""StorageEngine tests: batched primitives vs naive per-vertex queries
(across LSM levels + buffers + tombstones), engine-generic traversal, and
LSMTree.snapshot() analytics on the live store (ISSUE 1)."""
import numpy as np
import pytest

from repro.core import (
    GraphPAL,
    IntervalMap,
    LSMEngine,
    LSMTree,
    PALEngine,
    StorageEngine,
    as_engine,
    bfs,
    build_device_graph,
    friends_of_friends,
    pagerank_device,
    shortest_path,
)


def build_live_lsm(n=10_000, e=4000, seed=0, n_deletes=150,
                   column_dtypes=None, columns=None):
    """An LSM store in a deliberately messy live state: multiple flushes,
    push-down merges, tombstones, and a final batch still sitting in the
    in-memory buffers."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    iv = IntervalMap.for_capacity(n - 1, 16)
    t = LSMTree(iv, n_levels=3, branching=4, buffer_cap=600,
                max_partition_edges=900, column_dtypes=column_dtypes)
    k = e - min(400, max(1, e // 8))
    cols = columns or {}

    def sl(a, b):
        return {key: v[a:b] for key, v in cols.items()}

    t.insert_edges(src[:k], dst[:k], columns=sl(0, k))
    # final batch smaller than buffer_cap so it STAYS in the buffers
    t.insert_edges(src[k:], dst[k:], columns=sl(k, e))
    assert t.total_buffered() > 0
    # deletes last, targeting flushed edges, so tombstones are live at query
    # time (earlier deletes would be purged by the later merges)
    deleted = []
    if n_deletes:
        for i in rng.choice(k, size=n_deletes, replace=False):
            if t.delete_edge(int(src[i]), int(dst[i])):
                deleted.append((int(src[i]), int(dst[i])))
    return t, src, dst, deleted


@pytest.fixture(scope="module")
def live_lsm():
    return build_live_lsm()


class TestEngineDispatch:
    def test_as_engine_types(self, live_lsm):
        t, *_ = live_lsm
        eng = as_engine(t)
        assert isinstance(eng, LSMEngine)
        assert as_engine(eng) is eng  # idempotent
        assert t.storage_engine() is eng  # cached
        g = GraphPAL.from_edges([0, 1], [1, 2], n_partitions=2, max_id=9)
        assert isinstance(as_engine(g), PALEngine)

    def test_as_engine_rejects_unknown(self):
        with pytest.raises(TypeError):
            as_engine(object())

    def test_no_storage_class_branching_in_query_layer(self):
        import inspect

        import repro.core.query as query

        source = inspect.getsource(query)
        assert "isinstance" not in source  # acceptance: zero class branching


class TestBatchedEquivalence:
    """Acceptance: batched LSM out/in_neighbors_batch must match the naive
    per-vertex results across levels + buffers + tombstones."""

    def test_live_state_is_messy(self, live_lsm):
        t, *_ = live_lsm
        assert t.total_buffered() > 0, "want edges still in buffers"
        assert t.stats.pushdown_merges > 0, "want multiple populated levels"
        assert any(p.dead is not None and p.dead.any()
                   for p in t.all_partitions()), "want live tombstones"

    @pytest.mark.parametrize("direction", ["out", "in"])
    def test_lsm_batch_matches_per_vertex(self, live_lsm, direction):
        t, src, dst, _ = live_lsm
        eng = t.storage_engine()
        rng = np.random.default_rng(1)
        vs = np.unique(rng.integers(0, 10_000, 400))  # hits + misses
        if direction == "out":
            vals, offsets = eng.out_neighbors_batch(vs)
            naive = [t.out_neighbors(int(v)) for v in vs]
        else:
            vals, offsets = eng.in_neighbors_batch(vs)
            naive = [t.in_neighbors(int(v)) for v in vs]
        assert offsets.shape == (vs.shape[0] + 1,)
        assert int(offsets[-1]) == vals.shape[0]
        for i, v in enumerate(vs):
            got = np.sort(vals[offsets[i]:offsets[i + 1]])
            assert np.array_equal(got, np.sort(naive[i])), int(v)

    def test_pal_batch_matches_per_vertex(self):
        rng = np.random.default_rng(2)
        n, e = 500, 4000
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        g = GraphPAL.from_edges(src, dst, n_partitions=8, max_id=n - 1)
        eng = g.storage_engine()
        vs = np.arange(0, n, 3)
        vals, offsets = eng.out_neighbors_batch(vs)
        for i, v in enumerate(vs):
            got = np.sort(vals[offsets[i]:offsets[i + 1]])
            assert np.array_equal(got, np.sort(dst[src == v])), int(v)
        vals, offsets = eng.in_neighbors_batch(vs)
        for i, v in enumerate(vs):
            got = np.sort(vals[offsets[i]:offsets[i + 1]])
            assert np.array_equal(got, np.sort(src[dst == v])), int(v)

    def test_empty_frontier_and_missing_vertices(self, live_lsm):
        t, *_ = live_lsm
        eng = t.storage_engine()
        vals, offsets = eng.out_neighbors_batch(np.empty(0, np.int64))
        assert vals.size == 0 and np.array_equal(offsets, [0])
        # vertices with no in-edges at all
        _, _, dst, _ = live_lsm
        missing = np.setdiff1d(np.arange(10_000), dst)[:2]
        assert missing.size == 2
        vals, offsets = eng.in_neighbors_batch(missing)
        assert vals.size == 0 and np.array_equal(offsets, [0, 0, 0])


class TestEdgeColumnsBatch:
    def test_columns_follow_edges_across_levels_and_buffers(self):
        rng = np.random.default_rng(3)
        n, e = 10_000, 3000
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        w = (src * 7 + dst).astype(np.float32)
        t, *_ = build_live_lsm(n=n, e=e, seed=3, n_deletes=0,
                               column_dtypes={"w": np.float32},
                               columns={"w": w})
        # rebuild with the exact arrays used above
        eng = t.storage_engine()
        vs = np.unique(rng.integers(0, n, 200))
        batch = eng.edge_columns_batch(vs, names=["w"], direction="out")
        assert batch.src.shape == batch.dst.shape == batch.columns["w"].shape
        total = 0
        for i, v in enumerate(vs):
            sl = batch.slice_of(i)
            assert np.all(batch.src[sl] == v)
            total += sl.stop - sl.start
        assert total == batch.src.shape[0]
        np.testing.assert_allclose(
            batch.columns["w"],
            (batch.src * 7 + batch.dst).astype(np.float32))

    def test_in_direction_groups_by_destination(self, live_lsm):
        t, *_ = live_lsm
        eng = t.storage_engine()
        vs = np.asarray([5, 77, 4242])
        batch = eng.edge_columns_batch(vs, direction="in")
        for i, v in enumerate(vs):
            assert np.all(batch.dst[batch.slice_of(i)] == v)

    def test_pal_default_names_discovers_columns(self):
        """GraphPAL declares no column_dtypes; names=None must still
        surface the columns its partitions carry."""
        rng = np.random.default_rng(4)
        n, e = 100, 500
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        w = (src * 3 + dst).astype(np.float32)
        g = GraphPAL.from_edges(src, dst, n_partitions=4, max_id=n - 1,
                                columns={"w": w})
        batch = g.storage_engine().edge_columns_batch(np.arange(0, n, 5))
        assert "w" in batch.columns
        assert batch.columns["w"].dtype == np.float32
        np.testing.assert_allclose(
            batch.columns["w"],
            (batch.src * 3 + batch.dst).astype(np.float32))


class TestEngineGenericQueries:
    """FoF / BFS / shortest-path produce identical answers through the
    engine on both backends."""

    def test_fof_backends_agree(self, live_lsm):
        t, src, dst, deleted = live_lsm
        s, d = t.to_coo()
        g = GraphPAL.from_edges(s, d, n_partitions=16, max_id=10_000 - 1)
        for v in [0, 7, 1234]:
            a = friends_of_friends(t, v)
            b = friends_of_friends(g, v)
            assert np.array_equal(np.sort(a), np.sort(b)), v

    def test_bfs_backends_agree(self, live_lsm):
        t, *_ = live_lsm
        s, d = t.to_coo()
        g = GraphPAL.from_edges(s, d, n_partitions=16, max_id=10_000 - 1)
        v = int(s[0])
        assert bfs(t, v, max_depth=3) == bfs(g, v, max_depth=3)

    def test_shortest_path_on_engine(self):
        g = GraphPAL.from_edges([0, 1, 2, 3, 0], [1, 2, 3, 4, 9],
                                n_partitions=2, max_id=9)
        eng = as_engine(g)
        assert shortest_path(eng, 0, 4, max_depth=5) == 4
        assert shortest_path(eng, 0, 9, max_depth=5) == 1
        assert shortest_path(eng, 4, 0, max_depth=5) is None


class TestSnapshot:
    """Acceptance: LSMTree.snapshot() feeds PSW sweeps / psw_spmm tiles with
    results identical to the GraphPAL-built DeviceGraph, including edges
    still sitting in buffers."""

    def test_snapshot_bit_identical_to_pal(self):
        t, src, dst, _ = build_live_lsm(n_deletes=0, seed=7)
        assert t.total_buffered() > 0
        g = GraphPAL.from_edges(src, dst, n_partitions=16, max_id=10_000 - 1)
        dg_lsm = t.snapshot()
        dg_pal = build_device_graph(g)
        assert dg_lsm.n_edges == dg_pal.n_edges == src.shape[0]
        for name in ["src", "dst_local", "mask", "outdeg",
                     "send_idx", "edge_owner", "edge_slot"]:
            a = np.asarray(getattr(dg_lsm, name))
            b = np.asarray(getattr(dg_pal, name))
            assert np.array_equal(a, b), name

    def test_snapshot_pagerank_bit_for_bit(self):
        t, src, dst, _ = build_live_lsm(n_deletes=0, seed=8)
        g = GraphPAL.from_edges(src, dst, n_partitions=16, max_id=10_000 - 1)
        r_lsm = np.asarray(pagerank_device(t.snapshot(), n_iters=5))
        r_pal = np.asarray(pagerank_device(build_device_graph(g), n_iters=5))
        assert np.array_equal(r_lsm, r_pal)  # bit-for-bit

    def test_snapshot_respects_tombstones_and_buffers(self):
        t, src, dst, deleted = build_live_lsm(seed=9)
        dg = t.snapshot(with_window_plan=False)
        assert dg.n_edges == t.n_edges  # live edges only, buffers included
        assert t.total_buffered() > 0
        # snapshot is read-only: the store is untouched
        assert t.total_buffered() > 0 and dg.n_edges == t.n_edges

    def test_snapshot_spmm_on_live_store(self):
        """FoF-as-SpMM / Pallas tiles directly against the online store."""
        from repro.kernels.psw_spmm import psw_spmm_edges, spmm_dense_ref
        import jax.numpy as jnp

        rng = np.random.default_rng(10)
        n, e = 512, 3000
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        iv = IntervalMap.for_capacity(n - 1, 16)
        t = LSMTree(iv, n_levels=2, branching=4, buffer_cap=500,
                    max_partition_edges=1200)
        t.insert_edges(src[:2700], dst[:2700])
        t.insert_edges(src[2700:], dst[2700:])  # < cap: stays buffered
        assert t.total_buffered() > 0
        s, d = t.to_coo()
        x = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
        out = psw_spmm_edges(s, d, x, n, block=128)
        ref = spmm_dense_ref(jnp.asarray(src), jnp.asarray(dst), x, n)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
