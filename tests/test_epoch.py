"""ISSUE 5: epoch-published manifests, lock-free live reads, the parallel
maintenance pipeline, and the satellites (columns_for_hits, shared WAL-tail
cache). The centerpiece is the threaded stress test: readers hammer
FoF/BFS/coo through pinned manifests while the writer inserts + deletes and
maintenance merges + checkpoints + GCs concurrently, asserting every read
is bitwise-equal to a serial replay of some prefix of the op log."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (
    GraphDB,
    IntervalMap,
    LSMTree,
    ServiceDB,
    Snapshot,
    tail_cache_stats,
)
from repro.core.query import bfs, friends_of_friends


def make_tree(**kw):
    opts = dict(n_levels=3, branching=4, buffer_cap=2000,
                max_partition_edges=8000)
    opts.update(kw)
    iv = IntervalMap.for_capacity(9999, 16)
    return LSMTree(iv, **opts)


def coo_sorted(g):
    return sorted(zip(*map(list, g.to_coo())))


class TestManifestViews:
    def test_view_matches_tree_and_survives_churn(self):
        t = make_tree()
        rng = np.random.default_rng(0)
        s = rng.integers(0, 10000, 5000)
        d = rng.integers(0, 10000, 5000)
        t.insert_edges(s, d)
        view = t.read_view()
        ref = coo_sorted(t)
        assert coo_sorted(view) == ref
        v = int(s[0])
        assert np.array_equal(np.sort(view.out_neighbors(v)),
                              np.sort(t.out_neighbors(v)))
        assert np.array_equal(
            np.sort(friends_of_friends(view.storage_engine(), v)),
            np.sort(friends_of_friends(t.storage_engine(), v)))
        # writer churn after the pin: inserts, deletes, merges
        t.insert_edges(rng.integers(0, 10000, 3000),
                       rng.integers(0, 10000, 3000))
        for i in range(100):
            t.delete_edge(int(s[i]), int(d[i]))
        t.flush_all()
        assert coo_sorted(view) == ref, "pinned view drifted under churn"
        view.release()

    def test_epoch_reclamation(self):
        t = make_tree()
        t.insert_edges([1, 2, 3], [4, 5, 6])
        v1 = t.read_view()
        t.insert_edges([7], [8])  # retires v1's manifest
        assert len(t.epochs._retired) >= 1
        v1.release()
        t.insert_edges([9], [10])  # next publish trims the retired list
        assert t.epochs.min_pinned() is None
        assert t.epochs._retired == []

    def test_dead_reader_thread_pins_are_reclaimed(self):
        """Regression (ISSUE 7 satellite): a reader thread that pinned a
        view and died without releasing it must not retain epochs forever
        — its pin slot is reclaimed once the thread is gone, so GC can
        proceed."""
        t = make_tree()
        t.insert_edges([1, 2, 3], [4, 5, 6])

        def leaky_reader():
            t.read_view()  # pins, never releases

        th = threading.Thread(target=leaky_reader)
        th.start()
        th.join()
        t.insert_edges([7], [8])  # retires the pinned manifest
        assert t.epochs.min_pinned() is None, (
            "dead reader's pin still retains an epoch")
        t.insert_edges([9], [10])  # next publish trims the retired list
        assert t.epochs._retired == []
        # a LIVE pin on this thread is still honored after reclamation
        v = t.read_view()
        t.insert_edges([11], [12])
        assert t.epochs.min_pinned() is not None
        v.release()

    def test_view_includes_pending_drains(self):
        t = make_tree(buffer_cap=10 ** 9)
        t.insert_edges([1, 2], [3, 4])
        st = t.drain_buffer(t._top_index_of(
            int(t.intervals.to_internal(3))))
        assert st is not None
        # drained but not committed: views and live queries still see both
        view = t.read_view()
        assert coo_sorted(view) == sorted([(1, 3), (2, 4)])
        assert coo_sorted(t) == sorted([(1, 3), (2, 4)])
        assert t.n_edges == 2
        t.commit_txn(t.build_flush_txn(t._top_index_of(
            int(t.intervals.to_internal(3))), st))
        assert coo_sorted(t) == sorted([(1, 3), (2, 4)])
        view.release()

    def test_read_view_after_reopen_with_empty_tail(self, tmp_path):
        """Regression: recovery installs manifest partitions by direct
        slot assignment; without a post-recovery publish, a reopened
        store's read_view saw an EMPTY manifest when the WAL tail had
        nothing to replay."""
        svc = ServiceDB.create(str(tmp_path / "db"), max_id=999,
                               buffer_cap=100,
                               checkpoint_interval_ops=10 ** 9)
        svc.insert_edges([1, 2, 3], [4, 5, 6])
        svc.checkpoint()  # tail empty past the covered offset
        svc.close()
        svc2 = ServiceDB.open(str(tmp_path / "db"))
        with svc2.read_view() as view:
            assert coo_sorted(view) == sorted([(1, 4), (2, 5), (3, 6)])
        svc2.close()

    def test_deferred_file_gc_under_pinned_view(self, tmp_path):
        svc = ServiceDB.create(str(tmp_path / "db"), max_id=9999,
                               n_partitions=16, n_levels=3, branching=4,
                               buffer_cap=500, max_partition_edges=8000,
                               persist_min_edges=256,
                               checkpoint_interval_ops=10 ** 9,
                               maintenance=False)
        rng = np.random.default_rng(1)
        s = rng.integers(0, 10000, 6000)
        d = rng.integers(0, 10000, 6000)
        svc.insert_edges(s, d)
        svc.checkpoint()
        view = svc.read_view()
        ref = coo_sorted(view)
        pinned_files = {p.path for p in view.all_partitions()
                        if getattr(p.part, "path", None)}
        assert pinned_files, "expected disk partitions in the view"
        # churn so merges replace partitions, then checkpoint + GC twice
        svc.insert_edges(rng.integers(0, 10000, 6000),
                         rng.integers(0, 10000, 6000))
        svc.checkpoint()
        svc.checkpoint()
        # the pinned view's files survived GC (deferred reclamation) ...
        for path in pinned_files:
            assert os.path.exists(path), "GC deleted a pinned file"
        assert coo_sorted(view) == ref
        view.release()
        # ... and fall out of the keep-set once the pin is gone
        svc.checkpoint()
        assert not all(os.path.exists(p) for p in pinned_files), \
            "released files were never reclaimed"
        svc.close()


class TestViewAnalytics:
    def test_psw_streaming_and_device_graph_on_pinned_view(self):
        """Out-of-core PSW streaming and DeviceGraph compilation run
        against a pinned view and stay bitwise-stable while the writer
        churns — the ISSUE-5 'analytics on the live store without the
        lock' path."""
        from repro.core.psw import stream_interval_buckets
        t = make_tree()
        rng = np.random.default_rng(7)
        s = rng.integers(0, 10000, 4000)
        d = rng.integers(0, 10000, 4000)
        t.insert_edges(s, d)
        view = t.read_view()
        ref = [(i, bs.copy(), bd.copy())
               for i, bs, bd in stream_interval_buckets(t)]
        got = list(stream_interval_buckets(view))
        assert len(got) == len(ref)
        for (i, rs_, rd), (j, gs, gd) in zip(ref, got):
            assert i == j
            assert np.array_equal(rs_, gs) and np.array_equal(rd, gd)
        dg_ref = t.snapshot(with_window_plan=False)
        # writer churns; the pinned view's buckets and DeviceGraph hold
        t.insert_edges(rng.integers(0, 10000, 2000),
                       rng.integers(0, 10000, 2000))
        for i in range(50):
            t.delete_edge(int(s[i]), int(d[i]))
        got2 = list(stream_interval_buckets(view))
        for (i, rs_, rd), (j, gs, gd) in zip(ref, got2):
            assert np.array_equal(rs_, gs) and np.array_equal(rd, gd)
        dg_view = view.snapshot(with_window_plan=False)
        assert dg_view.n_edges == dg_ref.n_edges
        assert np.array_equal(np.asarray(dg_view.src),
                              np.asarray(dg_ref.src))
        assert np.array_equal(np.asarray(dg_view.mask),
                              np.asarray(dg_ref.mask))
        view.release()


class TestColumnsForHits:
    def test_columns_for_hits_covers_buffers(self):
        t = make_tree(column_dtypes={"ts": np.int64}, buffer_cap=10 ** 9)
        rng = np.random.default_rng(2)
        s = rng.integers(0, 10000, 3000)
        d = rng.integers(0, 10000, 3000)
        ts = rng.integers(0, 10 ** 6, 3000)
        t.insert_edges(s[:2000], d[:2000], columns={"ts": ts[:2000]})
        t.flush_all()  # first 2000 live in partitions
        t.insert_edges(s[2000:], d[2000:], columns={"ts": ts[2000:]})
        v = int(s[2500])  # a vertex with BUFFERED out-edges
        hits = t.out_edge_hits(v)
        got = t.columns_for_hits(hits, "ts")
        assert (hits[:, 0] == LSMTree.BUFFER_LEVEL).any(), \
            "expected buffer hits"
        # reference: every (src==v) edge's ts, multiset equality
        expect = sorted(int(x) for x in ts[s == v])
        assert sorted(int(x) for x in got) == expect
        # tuple-list form resolves identically
        assert sorted(int(x) for x in
                      t.columns_for_hits(t.out_edges(v), "ts")) == expect

    def test_in_edge_hits_buffers(self):
        t = make_tree(column_dtypes={"w": np.float64}, buffer_cap=10 ** 9)
        t.insert_edges([1, 2, 3], [7, 7, 9],
                       columns={"w": np.asarray([1.0, 2.0, 3.0])})
        hits = t.in_edge_hits(7)
        assert hits.shape[0] == 2
        assert sorted(t.columns_for_hits(hits, "w").tolist()) == [1.0, 2.0]


class TestTailCache:
    def test_snapshot_opens_share_replayed_tail(self, tmp_path):
        svc = ServiceDB.create(str(tmp_path / "db"), max_id=9999,
                               n_partitions=16, n_levels=3, branching=4,
                               buffer_cap=10 ** 9,
                               checkpoint_interval_ops=10 ** 9,
                               maintenance=False)
        rng = np.random.default_rng(3)
        svc.insert_edges(rng.integers(0, 10000, 4000),
                         rng.integers(0, 10000, 4000))
        snap1 = svc.begin_snapshot()
        before = tail_cache_stats()
        # second session of the SAME pin: the decoded tail is shared even
        # though it is a different session directory (hard-linked inodes)
        snap2 = svc.begin_snapshot()
        after = tail_cache_stats()
        assert after["hits"] == before["hits"] + 1
        assert coo_sorted(snap1) == coo_sorted(snap2)
        # a reopen of an existing dir hits too
        snap3 = Snapshot.open(snap2.dir)
        assert tail_cache_stats()["hits"] == before["hits"] + 2
        assert coo_sorted(snap3) == coo_sorted(snap1)
        for sn in (snap1, snap2, snap3):
            sn.release() if sn is not snap3 else sn.close()
        svc.close()


class TestConcurrentPrefixEquality:
    def test_reads_equal_serial_prefix_under_full_churn(self, tmp_path):
        """The ISSUE-5 stress test. The writer applies batches of inserts
        and targeted deletes while the pipeline merges, checkpoints, and
        GCs; each mutation records (manifest version -> op-log length)
        under the service lock. Readers pin views at arbitrary moments and
        assert the view's coo/FoF/BFS are bitwise-equal to a serial replay
        of exactly the ops marked at or before the pinned version."""
        svc = ServiceDB.create(str(tmp_path / "db"), max_id=9999,
                               n_partitions=16, n_levels=3, branching=4,
                               buffer_cap=400, max_partition_edges=4000,
                               persist_min_edges=256,
                               checkpoint_interval_ops=2500,
                               backpressure_edges=10 ** 9)
        rng = np.random.default_rng(4)
        n_rounds = 45
        batches = [(rng.integers(0, 10000, 150),
                    rng.integers(0, 10000, 150)) for _ in range(n_rounds)]
        oplog = []
        marks = {}  # manifest version -> len(oplog) at that publish
        done = threading.Event()
        errors = []

        def writer():
            try:
                for bi, (s, d) in enumerate(batches):
                    with svc._lock:
                        svc.insert_edges(s, d)
                        oplog.append(("insert", s, d))
                        marks[svc.tree.epochs.current.version] = len(oplog)
                    if bi % 3 == 2:
                        s0, d0 = int(s[0]), int(d[0])
                        with svc._merge_slot_of(d0), svc._lock:
                            svc.delete_edge(s0, d0)
                            oplog.append(("delete", s0, d0))
                            marks[svc.tree.epochs.current.version] = \
                                len(oplog)
                    time.sleep(0.002)  # let merges interleave mid-stream
            except BaseException as e:  # pragma: no cover
                errors.append(e)
            finally:
                done.set()

        checked = [0, 0]

        def reader(ri):
            try:
                while not done.is_set() or checked[ri] < 4:
                    with svc.read_view() as view:
                        got_coo = coo_sorted(view)
                        with svc._lock:  # test bookkeeping only
                            mk = dict(marks)
                            prefix_all = list(oplog)
                        usable = [v for v in mk if v <= view.version]
                        n_ops = mk[max(usable)] if usable else 0
                        prefix = prefix_all[:n_ops]
                        ref = make_tree(buffer_cap=10 ** 9)
                        for op in prefix:
                            if op[0] == "insert":
                                ref.insert_edges(op[1], op[2])
                            else:
                                ref.delete_edge(op[1], op[2])
                        assert got_coo == coo_sorted(ref), \
                            f"reader {ri}: coo != prefix of {n_ops} ops"
                        if prefix:
                            v = int(prefix[0][1][0])
                            assert np.array_equal(
                                np.sort(friends_of_friends(
                                    view.storage_engine(), v)),
                                np.sort(friends_of_friends(
                                    ref.storage_engine(), v)))
                            assert bfs(view.storage_engine(), v,
                                       max_depth=2) == \
                                bfs(ref.storage_engine(), v, max_depth=2)
                    checked[ri] += 1
            except BaseException as e:
                errors.append(e)
                done.set()

        wt = threading.Thread(target=writer)
        rs = [threading.Thread(target=reader, args=(i,)) for i in range(2)]
        wt.start()
        for r in rs:
            r.start()
        wt.join()
        for r in rs:
            r.join()
        svc.close()
        assert not errors, errors[0]
        assert checked[0] >= 4 and checked[1] >= 4
        assert svc.stats.flushes > 0, "maintenance never merged"
        assert svc.stats.checkpoints > 0, "maintenance never checkpointed"
        # final state equals the full serial replay
        db2 = GraphDB.open(str(tmp_path / "db"))
        ref = make_tree(buffer_cap=10 ** 9)
        for op in oplog:
            if op[0] == "insert":
                ref.insert_edges(op[1], op[2])
            else:
                ref.delete_edge(op[1], op[2])
        assert coo_sorted(db2) == coo_sorted(ref)
