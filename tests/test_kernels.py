"""Per-kernel allclose tests vs ref.py oracles: shape/dtype sweeps +
hypothesis property tests (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref
from repro.kernels.flash_attention import (attention_ref, flash_attention,
                                           flash_attention_pallas)
from repro.kernels.common import round_up
from repro.kernels.frontier_expand import (build_frontier_plan,
                                           frontier_expand_counts,
                                           frontier_expand_np,
                                           frontier_expand_ref)
from repro.kernels.frontier_expand.frontier_expand import frontier_expand_pallas
from repro.kernels.psw_spmm import psw_spmm_edges, spmm_dense_ref
from repro.kernels.segment_ell import (segment_ell, segment_ell_from_edges,
                                       segment_ell_ref)


class TestPswSpmm:
    @pytest.mark.parametrize("n,e,f", [(100, 500, 16), (300, 3000, 64),
                                       (513, 4000, 130), (64, 64, 256)])
    def test_matches_edge_oracle(self, n, e, f):
        rng = np.random.default_rng(n + e)
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
        out = psw_spmm_edges(src, dst, x, n, block=128)
        ref = spmm_dense_ref(jnp.asarray(src), jnp.asarray(dst), x, n)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_empty_dst_blocks_zeroed(self):
        # all edges target node 0 — other blocks must still be initialized
        src = np.arange(50)
        dst = np.zeros(50, np.int64)
        x = jnp.ones((300, 8), jnp.float32)
        out = psw_spmm_edges(src, dst, x, 300, block=128)
        assert float(jnp.abs(out[1:]).max()) == 0.0
        np.testing.assert_allclose(np.asarray(out[0]), 50.0)

    @given(st.integers(0, 2**31 - 1), st.integers(1, 300),
           st.sampled_from([1, 8, 40, 128]))
    @settings(max_examples=10, deadline=None)
    def test_property_random_graphs(self, seed, e, f):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 400))
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
        out = psw_spmm_edges(src, dst, x, n, block=128)
        ref = spmm_dense_ref(jnp.asarray(src), jnp.asarray(dst), x, n)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestSegmentEll:
    @pytest.mark.parametrize("n,k,m,f", [(100, 8, 50, 30), (256, 16, 256, 128),
                                         (33, 5, 20, 200), (128, 1, 10, 128)])
    def test_matches_oracle(self, n, k, m, f):
        rng = np.random.default_rng(n * k)
        idx = jnp.asarray(rng.integers(0, m, (n, k)), jnp.int32)
        mask = jnp.asarray(rng.random((n, k)) < 0.7)
        x = jnp.asarray(rng.normal(size=(m, f)).astype(np.float32))
        out = segment_ell(idx, mask, x)
        ref = segment_ell_ref(idx, mask, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)

    def test_from_edges_matches_spmm(self):
        rng = np.random.default_rng(7)
        n, e, f = 60, 200, 24
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        # cap above max in-degree so nothing is dropped
        x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
        out = segment_ell_from_edges(src, dst, x, n, max_degree=e)
        ref = spmm_dense_ref(jnp.asarray(src), jnp.asarray(dst), x, n)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_all_masked(self):
        idx = jnp.zeros((128, 4), jnp.int32)
        mask = jnp.zeros((128, 4), bool)
        x = jnp.ones((8, 128), jnp.float32)
        out = segment_ell(idx, mask, x)
        assert float(jnp.abs(out).max()) == 0.0


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,hkv,d", [
        (1, 128, 2, 2, 64), (2, 256, 4, 2, 64), (2, 256, 8, 1, 128),
        (1, 512, 4, 4, 128),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, b, s, h, hkv, d, causal):
        key = jax.random.PRNGKey(b * s + h)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=causal)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.bfloat16)
        out = flash_attention_pallas(q, k, v, causal=True)
        ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), rtol=2e-2, atol=2e-2)

    def test_custom_vjp_grads(self):
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 128, 1, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 128, 1, 64), jnp.float32)

        def f(q, k, v):
            return (flash_attention(q, k, v, True) ** 2).sum()

        def f_ref(q, k, v):
            return (attention_ref(q, k, v, True) ** 2).sum()

        g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)

    def test_cross_attention_longer_kv(self):
        """Decode-ish: S < T (query block over a longer kv history)."""
        key = jax.random.PRNGKey(2)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, 128, 4, 64), jnp.float32)
        k = jax.random.normal(ks[1], (2, 512, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (2, 512, 2, 64), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=False)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestEmbeddingBag:
    @pytest.mark.parametrize("b,k,v,d", [(64, 4, 1000, 32), (128, 16, 500, 64),
                                         (200, 2, 50, 128), (128, 1, 10, 16)])
    @pytest.mark.parametrize("mode", ["sum", "mean"])
    def test_matches_oracle(self, b, k, v, d, mode):
        rng = np.random.default_rng(b + k)
        idx = jnp.asarray(rng.integers(0, v, (b, k)), jnp.int32)
        w = jnp.asarray(rng.random((b, k)).astype(np.float32))
        table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
        out = embedding_bag(idx, w, table, mode=mode)
        ref = embedding_bag_ref(idx, w, table, mode=mode)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_weighted_bags(self, seed):
        rng = np.random.default_rng(seed)
        b = int(rng.integers(1, 80))
        k = int(rng.integers(1, 12))
        v = int(rng.integers(1, 300))
        d = int(rng.integers(1, 100))
        idx = jnp.asarray(rng.integers(0, v, (b, k)), jnp.int32)
        w = jnp.asarray((rng.random((b, k)) < 0.8).astype(np.float32)
                        * rng.random((b, k)).astype(np.float32))
        table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
        out = embedding_bag(idx, w, table)
        ref = embedding_bag_ref(idx, w, table)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestFrontierExpand:
    @pytest.mark.parametrize("n,e,b", [(100, 500, 16), (300, 4000, 130),
                                       (64, 64, 1), (513, 9000, 64)])
    def test_pallas_matches_oracles(self, n, e, b):
        rng = np.random.default_rng(n + e)
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        plan = build_frontier_plan(src, dst, n, n, k_slots=8)
        # row budget: virtual rows are linear in edges + touched dsts
        assert plan.idx.shape[0] <= round_up(
            np.unique(dst * n + src).size // 8 + np.unique(dst).size + 1, 128)
        x = rng.random((plan.idx.shape[1] and n, b)).astype(np.float32)
        xp = np.zeros((round_up(n, 128), round_up(b, 128)), np.float32)
        xp[:n, :b] = x
        out = frontier_expand_pallas(jnp.asarray(plan.idx),
                                     jnp.asarray(plan.mask),
                                     jnp.asarray(xp), interpret=True)
        ref = frontier_expand_ref(jnp.asarray(plan.idx),
                                  jnp.asarray(plan.mask), jnp.asarray(xp))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        npo = frontier_expand_np(plan.idx, plan.mask, xp)
        np.testing.assert_allclose(npo, np.asarray(ref), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_counts_match_dedup_matmul(self, use_kernel):
        rng = np.random.default_rng(7)
        n, e = 220, 3000
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        plan = build_frontier_plan(src, dst, n, n)
        x = (rng.random((n, 5)) < 0.3).astype(np.float32)
        got = frontier_expand_counts(plan, x, use_kernel=use_kernel,
                                     interpret=True)
        a = np.zeros((n, n), np.float32)
        a[dst, src] = 1.0  # dedup: multi-edges count once
        np.testing.assert_allclose(got, a @ x, rtol=1e-5, atol=1e-5)

    def test_empty_plan(self):
        plan = build_frontier_plan(np.empty(0, np.int64), np.empty(0, np.int64),
                                   10, 12)
        out = frontier_expand_counts(plan, np.ones((10, 3), np.float32),
                                     use_kernel=False)
        assert out.shape == (12, 3) and not out.any()

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_random_plans(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 300))
        e = int(rng.integers(0, 2000))
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        b = int(rng.integers(1, 40))
        plan = build_frontier_plan(src, dst, n, n,
                                   k_slots=int(rng.integers(1, 33)))
        x = rng.random((n, b)).astype(np.float32)
        got = frontier_expand_counts(plan, x, use_kernel=False)
        a = np.zeros((n, n), np.float32)
        a[dst, src] = 1.0
        np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)
