"""Request-lifecycle tests (ISSUE 10): deadlines, backoff, circuit
breakers, hedged shard reads, frame hardening, router shutdown hygiene,
and the admission-controlled front desk.

Layout mirrors the layer boundaries:

  * units with no processes — `Deadline` / `backoff_delays` /
    `CircuitBreaker`, the `delay:`/`stall:` failpoint actions, frame
    reassembly under 1-byte dribble and EINTR (the short-read satellite);
  * a module-scoped 2-shard router (worker spawn is seconds) for the
    wire-level lifecycle: deadline propagation and typed expiry, the
    per-shard failpoint RPC, hedged broadcasts under an injected
    latency fault, remote-error kind mapping;
  * function-scoped single-shard routers for the destructive cases:
    retry-after-respawn budget semantics (`DeadlineExceeded`, never
    `ShardUnavailable`, when the budget is gone), breaker trip →
    fast-fail → probe recovery, close() idempotence / fd reaping /
    mid-request close;
  * `FrontDesk` over a plain ServiceDB (no processes): coalescing,
    bitwise answers, every shed reason typed, deadline discipline at
    admission / in queue / at delivery, and over the module router.
"""
import gc
import os
import random
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import (
    CircuitBreaker,
    Deadline,
    DeadlineExceeded,
    FrontDesk,
    OverloadError,
    ServiceDB,
    ShardOverloadError,
    ShardRouter,
    ShardUnavailable,
    backoff_delays,
    current_deadline,
    deadline_scope,
    failpoint,
    fp_clear,
    fp_set,
    telemetry,
    two_hop_counts,
)
from repro.core import shardrouter as sr
from repro.core.integrity import GraphDBError

N_ID = 20_000
DB_KW = dict(n_partitions=8, n_levels=2, branching=4, buffer_cap=4000,
             max_partition_edges=50_000, persist_min_edges=512)


def _edges(seed=11, n=20_000):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, N_ID, n, dtype=np.int64),
            rng.integers(0, N_ID, n, dtype=np.int64))


def _counter_total(snap, name):
    v = snap["counters"].get(name, 0)   # labeled: {label: n}; plain: n
    return sum(v.values()) if isinstance(v, dict) else v


# ---------------------------------------------------------------------------
# units: Deadline / backoff / breaker (no processes)
# ---------------------------------------------------------------------------
def test_deadline_budget_and_check():
    dl = Deadline.after(10.0)
    assert 9.0 < dl.remaining() <= 10.0
    assert not dl.expired()
    dl.check("fine")  # no raise
    # wire roundtrip: remaining seconds, clock-agnostic
    budget = dl.to_budget()
    back = Deadline.from_budget(budget)
    assert back is not None and abs(back.remaining() - budget) < 0.1
    assert Deadline.from_budget(None) is None

    gone = Deadline.after(-0.5)
    assert gone.expired() and gone.remaining() < 0
    with pytest.raises(DeadlineExceeded) as ei:
        gone.check("late op")
    assert "late op" in str(ei.value)
    assert ei.value.late_by >= 0.5
    # typed as both a GraphDBError and a TimeoutError
    assert isinstance(ei.value, GraphDBError)
    assert isinstance(ei.value, TimeoutError)


def test_deadline_timeout_floor_and_cap():
    assert Deadline.after(100.0).timeout(cap=5.0) == 5.0
    assert Deadline.after(-3.0).timeout() == pytest.approx(1e-3)
    t = Deadline.after(0.5).timeout(cap=5.0)
    assert 0.4 < t <= 0.5


def test_deadline_scope_is_thread_local_stack():
    assert current_deadline() is None
    outer, inner = Deadline.after(5.0), Deadline.after(1.0)
    with deadline_scope(outer):
        assert current_deadline() is outer
        with deadline_scope(inner):
            assert current_deadline() is inner
        with deadline_scope(None):  # None is a no-op, not a mask
            assert current_deadline() is outer
    assert current_deadline() is None

    seen = []

    def peek():
        seen.append(current_deadline())

    with deadline_scope(outer):
        t = threading.Thread(target=peek)
        t.start()
        t.join()
    assert seen == [None]  # ambient budget does not leak across threads


def test_backoff_delays_equal_jitter():
    delays = list(backoff_delays(0.01, 0.25, 8, rng=random.Random(42)))
    assert len(delays) == 8
    for k, d in enumerate(delays):
        full = min(0.25, 0.01 * 2.0 ** k)
        assert full * 0.5 <= d <= full  # d/2 + U(0, d/2)
    assert delays[-1] <= 0.25
    # seeded => reproducible
    again = list(backoff_delays(0.01, 0.25, 8, rng=random.Random(42)))
    assert delays == again


def test_circuit_breaker_state_machine():
    br = CircuitBreaker(failure_threshold=3, open_s=0.05)
    assert br.state == CircuitBreaker.CLOSED and br.allow()
    assert not br.record_failure()
    assert not br.record_failure()
    br.record_success()            # success clears the consecutive streak
    assert not br.record_failure()
    assert not br.record_failure()
    assert br.record_failure()     # third consecutive: trips, returns True
    assert br.state == CircuitBreaker.OPEN
    assert not br.allow() and br.trips == 1

    time.sleep(0.06)               # cool-down: one half-open probe slot
    assert br.state == CircuitBreaker.HALF_OPEN
    assert br.allow()
    assert not br.allow()          # the slot is exclusive
    br.record_success()
    assert br.state == CircuitBreaker.CLOSED

    for _ in range(3):
        br.record_failure()
    time.sleep(0.06)
    assert br.allow()              # probe...
    assert br.record_failure()     # ...fails: straight back to OPEN
    assert br.state == CircuitBreaker.OPEN and br.trips == 3
    br.reset()
    assert br.state == CircuitBreaker.CLOSED


def test_overload_error_taxonomy():
    e = ShardOverloadError(3, "breaker_open", "fast-failed read")
    assert isinstance(e, OverloadError) and isinstance(e, GraphDBError)
    assert e.shard == 3 and e.reason == "breaker_open"
    assert OverloadError("queue_full").reason == "queue_full"


# ---------------------------------------------------------------------------
# units: delay/stall failpoint actions
# ---------------------------------------------------------------------------
def test_failpoint_delay_action_sleeps_then_continues():
    fp_set("frontdesk.dispatch", "delay:30")
    t0 = time.perf_counter()
    failpoint("frontdesk.dispatch")   # must NOT raise — latency, not fault
    assert time.perf_counter() - t0 >= 0.025
    t0 = time.perf_counter()
    failpoint("frontdesk.dispatch")   # count=1 default: disarmed now
    assert time.perf_counter() - t0 < 0.02


def test_failpoint_stall_action_alias():
    fp_set("frontdesk.dispatch", "stall:20", count=1)
    t0 = time.perf_counter()
    failpoint("frontdesk.dispatch")
    assert time.perf_counter() - t0 >= 0.015


# ---------------------------------------------------------------------------
# units: frame hardening (short reads, EINTR) — the transport satellite
# ---------------------------------------------------------------------------
class _FlakySock:
    """Socket wrapper that raises EINTR (InterruptedError) every other
    call and dribbles writes 1 byte at a time — the adversarial peer the
    bounded send/recv loops must absorb."""

    def __init__(self, sock):
        self._sock = sock
        self._calls = 0

    def recv(self, n):
        self._calls += 1
        if self._calls % 2:
            raise InterruptedError("EINTR")
        return self._sock.recv(min(n, 3))   # short reads too

    def send(self, data):
        self._calls += 1
        if self._calls % 2:
            raise InterruptedError("EINTR")
        return self._sock.send(bytes(data[:1]))


def test_recv_frame_reassembles_one_byte_dribble():
    a, b = socket.socketpair()
    try:
        meta = {"op": "expand", "kw": {"direction": "out"}}
        arrays = {"vs": np.arange(64, dtype=np.int64)}
        payload = sr.encode_payload(meta, arrays)
        wire = sr._HEADER.pack(sr._MAGIC, len(payload),
                               sr.checksum32(payload),
                               sr.ST_REQUEST) + payload

        def dribble():
            for i in range(len(wire)):          # 1 byte per segment
                a.sendall(wire[i:i + 1])
                if i % 50 == 0:
                    time.sleep(0.001)

        t = threading.Thread(target=dribble)
        t.start()
        status, m2, a2 = sr.recv_frame(b)
        t.join()
        assert status == sr.ST_REQUEST
        assert m2["op"] == "expand"
        assert np.array_equal(a2["vs"], arrays["vs"])
    finally:
        a.close()
        b.close()


def test_frame_io_survives_eintr():
    a, b = socket.socketpair()
    try:
        data = b"lifecycle" * 20
        t = threading.Thread(target=sr._send_all,
                             args=(_FlakySock(a), data))
        t.start()
        got = sr._recv_exact(_FlakySock(b), len(data))
        t.join()
        assert got == data
    finally:
        a.close()
        b.close()


def test_send_all_raises_typed_on_closed_peer():
    a, b = socket.socketpair()
    b.close()
    try:
        with pytest.raises((ConnectionError, OSError)):
            # a closed peer can buffer a little; keep writing until the
            # RST surfaces — never a silent partial frame
            for _ in range(64):
                sr._send_all(a, b"x" * 65536)
    finally:
        a.close()


# ---------------------------------------------------------------------------
# the 2-shard router under the full lifecycle
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def cluster(tmp_path_factory):
    """2-shard router + the unsharded reference fed the same edges."""
    base = tmp_path_factory.mktemp("lifecycle")
    src, dst = _edges()
    ref = ServiceDB.create(str(base / "ref"), max_id=N_ID, **DB_KW)
    ref.insert_edges(src, dst)
    router = ShardRouter.create(str(base / "sharded"), max_id=N_ID,
                                n_shards=2, **DB_KW)
    router.insert_edges(src, dst)
    yield router, ref, src, dst
    router.close()
    ref.close()


def test_call_sheds_expired_deadline_before_send(cluster):
    router, _, _, _ = cluster
    before = _counter_total(telemetry.snapshot(),
                            "request.deadline_exceeded")
    with pytest.raises(DeadlineExceeded):
        router._call(0, "ping", {}, deadline=Deadline.after(-0.1))
    after = _counter_total(telemetry.snapshot(),
                           "request.deadline_exceeded")
    assert after > before


def test_ambient_deadline_scope_reaches_rpc(cluster):
    router, _, _, _ = cluster
    with deadline_scope(Deadline.after(-0.1)):
        with pytest.raises(DeadlineExceeded):
            router._call(0, "n_edges", {})


def test_worker_sheds_expired_budget_pre_dispatch(cluster):
    """An op arriving with its budget already gone is refused typed by the
    WORKER (never executed); the kind crosses the wire and maps back."""
    router, _, _, _ = cluster
    conn = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    try:
        conn.connect(router.shards[0].sock_path)
        sr.send_frame(conn, sr.ST_REQUEST,
                      {"op": "ping", "deadline": -0.5})
        status, meta, _ = sr.recv_frame(conn)
        assert status == sr.ST_ERROR
        assert meta["kind"] == "DeadlineExceeded"
    finally:
        conn.close()
    # the router maps that kind back to the LOCAL typed error
    err = router._remote_error(0, {"kind": "DeadlineExceeded",
                                   "message": "shed pre-dispatch"})
    assert isinstance(err, DeadlineExceeded)
    err = router._remote_error(1, {"kind": "OverloadError", "message": "q"})
    assert isinstance(err, ShardOverloadError) and err.shard == 1


def test_stalled_worker_times_out_typed(cluster):
    """A worker stalled past the caller's budget surfaces DeadlineExceeded
    (socket timeout derived from the deadline), and the connection is
    poisoned — NOT the worker respawned (it is alive, just slow)."""
    router, ref, src, _ = cluster
    restarts_before = router.restarts
    router.arm_failpoint(0, "shard.worker.op", "delay:120", count=1)
    t0 = time.perf_counter()
    with pytest.raises(DeadlineExceeded):
        router._call(0, "n_edges", {}, deadline=Deadline.after(0.03))
    elapsed = time.perf_counter() - t0
    assert elapsed < 1.0           # gave up on the budget, not op_timeout_s
    assert router.restarts == restarts_before
    # worker alive and consistent afterwards
    meta, _ = router._call(0, "n_edges", {})
    assert meta["n_edges"] > 0


def test_failpoint_rpc_arms_one_shard_only(cluster):
    router, _, _, _ = cluster
    router.arm_failpoint(0, "shard.worker.op", "raise", count=1)
    with pytest.raises(sr.ShardRemoteError) as ei:
        router._call(0, "n_edges", {}, retry=False)
    assert ei.value.kind == "FailpointError"
    # shard 1 was never armed
    meta, _ = router._call(1, "n_edges", {}, retry=False)
    assert "n_edges" in meta
    router.arm_failpoint(0, "shard.worker.op", clear=True)
    meta, _ = router._call(0, "n_edges", {}, retry=False)
    assert "n_edges" in meta


def test_hedged_broadcast_beats_probabilistic_stall(cluster):
    """With one shard probabilistically stalling 40ms per op, hedges are
    issued after the histogram-derived delay and some win — and every
    answer stays bitwise-correct."""
    router, ref, src, _ = cluster
    vs = [int(v) for v in src[:40]]
    expect = {v: np.sort(ref.in_neighbors(v)) for v in vs}
    router.arm_failpoint(1, "shard.worker.op", "delay:40", count=None,
                         prob=0.5, seed=20260809)
    s0 = telemetry.snapshot()
    try:
        for v in vs:
            got = router.in_neighbors(v)   # broadcast: hedged _gather
            assert np.array_equal(got, expect[v])
    finally:
        router.arm_failpoint(1, "shard.worker.op", clear=True)
    s1 = telemetry.snapshot()
    sent = (_counter_total(s1, "shard.hedges.sent")
            - _counter_total(s0, "shard.hedges.sent"))
    won = (_counter_total(s1, "shard.hedges.won")
           - _counter_total(s0, "shard.hedges.won"))
    assert sent > 0
    assert won > 0


# ---------------------------------------------------------------------------
# destructive router cases (fresh single-shard clusters)
# ---------------------------------------------------------------------------
def _mini_router(tmp_path, name, **router_kw):
    return ShardRouter.create(
        str(tmp_path / name), max_id=1000, n_shards=1, n_partitions=2,
        n_levels=2, branching=2, buffer_cap=500,
        router_kw=router_kw or None)


def test_retry_after_respawn_respects_remaining_budget(tmp_path):
    """The satellite: a read retried across a worker respawn must raise
    DeadlineExceeded — not ShardUnavailable — when the remaining budget
    cannot cover the respawn wait; with no deadline the same read
    transparently survives the restart."""
    router = _mini_router(tmp_path, "respawn", hedge=False)
    try:
        router.insert_edges([1, 2, 3], [4, 5, 6])
        sp = router.shards[0]
        sp.proc.terminate()
        sp.proc.join(timeout=10.0)
        with pytest.raises(DeadlineExceeded):
            # budget far below worker spawn time: the retry machinery must
            # honor the REMAINING budget across the respawn wait
            router._call(0, "n_edges", {}, deadline=Deadline.after(0.2))
        # no deadline: supervised respawn + retry completes the read
        meta, _ = router._call(0, "n_edges", {})
        assert meta["n_edges"] == 3
        assert router.restarts >= 1
    finally:
        router.close()


def test_breaker_trips_fast_fails_and_recovers(tmp_path):
    router = _mini_router(tmp_path, "breaker", hedge=False,
                          breaker_failures=3, breaker_open_s=0.3)
    try:
        router.arm_failpoint(0, "shard.worker.op", "delay:100", count=None)
        s0 = telemetry.snapshot()
        # three consecutive deadline-bounded timeouts feed the breaker
        for _ in range(3):
            with pytest.raises(DeadlineExceeded):
                router._call(0, "n_edges", {}, retry=False,
                             deadline=Deadline.after(0.03))
        assert router.breakers[0].state == CircuitBreaker.OPEN
        # open breaker: non-probe calls fail FAST with the typed overload
        t0 = time.perf_counter()
        with pytest.raises(ShardOverloadError) as ei:
            router._call(0, "n_edges", {})
        assert time.perf_counter() - t0 < 0.05
        assert ei.value.reason == "breaker_open" and ei.value.shard == 0
        s1 = telemetry.snapshot()
        assert (_counter_total(s1, "shard.breaker.trips")
                > _counter_total(s0, "shard.breaker.trips"))
        assert (_counter_total(s1, "shard.breaker.fastfail")
                > _counter_total(s0, "shard.breaker.fastfail"))
        # probes bypass the breaker: the fault can be cleared while open
        router.arm_failpoint(0, "shard.worker.op", clear=True)
        time.sleep(0.35)           # cool-down -> half-open
        health = router.health()   # the probe's success closes the breaker
        assert health[0]["alive"]
        assert router.breakers[0].state == CircuitBreaker.CLOSED
        meta, _ = router._call(0, "n_edges", {})
        assert "n_edges" in meta
    finally:
        router.close()


def test_close_is_idempotent_and_leaks_nothing(tmp_path, cluster):
    """The shutdown satellite: close-twice is a no-op, worker processes
    are reaped (no zombies), every router-opened fd — including ones
    cached by OTHER threads — is closed, and socket files are gone.
    (`cluster` is requested only to pre-warm multiprocessing's global
    helper fds so the fd baseline is stable.)"""
    gc.collect()
    fd_dir = "/proc/self/fd"
    before = len(os.listdir(fd_dir))
    router = _mini_router(tmp_path, "leak", hedge=True)
    router.insert_edges([1, 2], [3, 4])

    def reader():
        router._call(0, "n_edges", {})   # caches a conn in ANOTHER thread

    t = threading.Thread(target=reader)
    t.start()
    t.join()
    router.out_neighbors(1)              # touches the hedge pool too
    sock_file = router.shards[0].sock_path
    assert os.path.exists(sock_file)
    router.close()
    router.close()                       # idempotent
    assert all(sp.proc is None for sp in router.shards)   # reaped
    assert not os.path.exists(sock_file)
    assert router._socks == set()
    gc.collect()
    deadline = time.monotonic() + 5.0
    while len(os.listdir(fd_dir)) > before:
        if time.monotonic() > deadline:
            break
        time.sleep(0.05)
    assert len(os.listdir(fd_dir)) <= before
    # a closed router refuses new work typed
    with pytest.raises(ShardUnavailable):
        router._call(0, "n_edges", {})


def test_close_unblocks_mid_request_thread_typed(tmp_path):
    router = _mini_router(tmp_path, "midreq", hedge=False)
    try:
        router.insert_edges([1], [2])
        # far longer than the worker's 2s handler-join grace plus its
        # store-close time, so the close severs the in-flight request
        # instead of outwaiting it
        router.arm_failpoint(0, "shard.worker.op", "delay:10000",
                             count=None)
        caught = []

        def blocked_read():
            try:
                router._call(0, "n_edges", {})
                caught.append(None)
            except Exception as exc:  # noqa: BLE001 — recording the type
                caught.append(exc)

        t = threading.Thread(target=blocked_read)
        t.start()
        time.sleep(0.3)              # let it block inside recv_frame
        router.close()               # must unblock it — typed, not a hang
        t.join(timeout=15.0)
        assert not t.is_alive()
        assert len(caught) == 1
        assert isinstance(caught[0], GraphDBError)
    finally:
        router.close()


# ---------------------------------------------------------------------------
# the front desk over a plain ServiceDB (no processes)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def svc(tmp_path_factory):
    base = tmp_path_factory.mktemp("frontdesk")
    db = ServiceDB.create(str(base / "svc"), max_id=N_ID, **DB_KW)
    src, dst = _edges(seed=3, n=10_000)
    db.insert_edges(src, dst)
    yield db, src, dst
    db.close()


def test_frontdesk_coalesces_and_answers_bitwise(svc):
    db, src, _ = svc
    vs = [int(v) for v in src[:48]]
    fd = FrontDesk(db, max_batch=64)
    try:
        # stall the first dispatch so the rest of the burst queues up and
        # coalesces into same-kind batches
        fp_set("frontdesk.dispatch", "delay:40", count=1)
        futs = [fd.submit("out_neighbors", v=v) for v in vs]
        got = [f.result(timeout=30) for f in futs]
        for v, g in zip(vs, got):
            assert np.array_equal(g, np.sort(db.out_neighbors(v)))
        assert fd.stats.admitted == len(vs)
        assert fd.stats.batched_ops >= len(vs)
        assert fd.stats.batches < fd.stats.admitted   # coalescing happened

        # fof + getrange ride the same batched engine surface
        seeds = vs[:8]
        with db.read_view() as view:
            eng = view.storage_engine()
            expect_fof = two_hop_counts(eng, np.asarray(seeds, np.int64))
            eb = eng.edge_columns_batch(np.asarray(seeds, np.int64))
        for i, v in enumerate(seeds):
            assert np.array_equal(fd.friends_of_friends(v),
                                  expect_fof.ids[expect_fof.slice_of(i)])
            rng = fd.getrange(v)
            sl = slice(int(eb.offsets[i]), int(eb.offsets[i + 1]))
            assert np.array_equal(rng["dst"], eb.dst[sl])
    finally:
        fp_clear("frontdesk.dispatch")
        fd.close()


def test_frontdesk_queue_full_sheds_typed_and_fast(svc):
    db, src, _ = svc
    fd = FrontDesk(db, queue_cap=3)
    try:
        fp_set("frontdesk.dispatch", "delay:300", count=None)
        first = fd.submit("out_neighbors", v=int(src[0]))
        give_up = time.monotonic() + 5.0
        while fd.depth() > 0 and time.monotonic() < give_up:
            time.sleep(0.005)      # dispatcher picked it up; now stalled
        futs = [fd.submit("out_neighbors", v=int(src[i]))
                for i in range(1, 4)]           # fills the cap-3 queue
        t0 = time.perf_counter()
        with pytest.raises(OverloadError) as ei:
            fd.submit("out_neighbors", v=int(src[4]))
        assert time.perf_counter() - t0 < 0.05  # shed in the caller, fast
        assert ei.value.reason == "queue_full"
        assert fd.stats.shed == 1
    finally:
        fp_clear("frontdesk.dispatch")
        fd.close()
    assert first.result(timeout=30) is not None
    for f in futs:
        f.result(timeout=30)       # drained on close, never dropped


def test_frontdesk_queue_delay_shed_and_expiry_in_queue(svc):
    db, src, _ = svc
    fd = FrontDesk(db, queue_cap=100)
    try:
        fp_set("frontdesk.dispatch", "delay:200", count=None)
        fd.submit("out_neighbors", v=int(src[0]))
        give_up = time.monotonic() + 5.0
        while fd.depth() > 0 and time.monotonic() < give_up:
            time.sleep(0.005)
        queued = [fd.submit("out_neighbors", v=int(src[i]))
                  for i in range(1, 4)]
        # predicted drain (3 deep x 100ms EWMA) dwarfs a 50ms budget:
        # admission sheds typed instead of queueing doomed work
        fd._req_s_ewma = 0.1
        with pytest.raises(OverloadError) as ei:
            fd.submit("out_neighbors", deadline=Deadline.after(0.05),
                      v=int(src[4]))
        assert ei.value.reason == "queue_delay"
        fd._req_s_ewma = 0.0
        # a request that EXPIRES while queued is answered typed without
        # ever touching the engine
        doomed = fd.submit("out_neighbors", deadline=Deadline.after(0.04),
                           v=int(src[5]))
        exc = doomed.exception(timeout=30)
        assert isinstance(exc, DeadlineExceeded)
        for f in queued:
            f.result(timeout=30)
        # already-expired at admission: raises in the submitting thread
        with pytest.raises(DeadlineExceeded):
            fd.submit("out_neighbors", deadline=Deadline.after(-1.0),
                      v=int(src[6]))
        assert fd.stats.deadline_misses >= 2
    finally:
        fp_clear("frontdesk.dispatch")
        fd.close()


def test_frontdesk_write_admission_read_only_shed(tmp_path):
    db = ServiceDB.create(str(tmp_path / "ro"), max_id=1000,
                          n_partitions=2, n_levels=2, branching=2,
                          buffer_cap=500)
    try:
        db.insert_edges([1, 2], [3, 4])
        db._enter_read_only("test degradation")
        assert db.admission_state()["read_only"]
        fd = FrontDesk(db)
        try:
            with pytest.raises(OverloadError) as ei:
                fd.insert_edges([5], [6])
            assert ei.value.reason == "read_only"
            # reads still flow in read-only degradation
            assert np.array_equal(fd.out_neighbors(1), [3])
        finally:
            fd.close()
    finally:
        db.close()


def test_frontdesk_insert_coalesced_one_group_commit(tmp_path):
    db = ServiceDB.create(str(tmp_path / "ins"), max_id=1000,
                          n_partitions=2, n_levels=2, branching=2,
                          buffer_cap=500)
    try:
        fd = FrontDesk(db, max_batch=16)
        try:
            fp_set("frontdesk.dispatch", "delay:40", count=1)
            futs = [fd.submit("insert",
                              src=np.asarray([i], np.int64),
                              dst=np.asarray([i + 100], np.int64))
                    for i in range(8)]
            sizes = [f.result(timeout=30) for f in futs]
            assert sizes == [1] * 8
            assert db.n_edges == 8
            # 8 requests, strictly fewer engine round trips
            assert fd.stats.batches < 8
            for i in range(8):
                assert np.array_equal(fd.out_neighbors(i), [i + 100])
        finally:
            fp_clear("frontdesk.dispatch")
            fd.close()
    finally:
        db.close()


def test_frontdesk_close_drain_false_sheds_queue_typed(svc):
    db, src, _ = svc
    fd = FrontDesk(db, queue_cap=50)
    fp_set("frontdesk.dispatch", "delay:200", count=None)
    try:
        inflight = fd.submit("out_neighbors", v=int(src[0]))
        give_up = time.monotonic() + 5.0
        while fd.depth() > 0 and time.monotonic() < give_up:
            time.sleep(0.005)
        queued = [fd.submit("out_neighbors", v=int(src[i]))
                  for i in range(1, 4)]
    finally:
        fp_clear("frontdesk.dispatch")
    fd.close(drain=False)
    fd.close()                     # idempotent
    for f in queued:
        exc = f.exception(timeout=30)
        assert isinstance(exc, OverloadError) and exc.reason == "closed"
    inflight.result(timeout=30)    # the in-flight batch still completes
    with pytest.raises(OverloadError) as ei:
        fd.submit("out_neighbors", v=int(src[0]))
    assert ei.value.reason == "closed"


def test_frontdesk_over_shard_router(cluster):
    """The front desk composes with the sharded store: batches run on the
    live hedged scatter/gather engine and stay bitwise-correct."""
    router, ref, src, _ = cluster
    vs = [int(v) for v in src[:24]]
    fd = FrontDesk(router, max_batch=32)
    try:
        fp_set("frontdesk.dispatch", "delay:30", count=1)
        futs = [fd.submit("out_neighbors", v=v) for v in vs]
        for v, f in zip(vs, futs):
            assert np.array_equal(f.result(timeout=60),
                                  np.sort(ref.out_neighbors(v)))
        # fof over the sharded engine vs the unsharded reference
        with ref.read_view() as view:
            expect = two_hop_counts(view.storage_engine(),
                                    np.asarray(vs[:6], np.int64))
        for i, v in enumerate(vs[:6]):
            assert np.array_equal(fd.friends_of_friends(v),
                                  expect.ids[expect.slice_of(i)])
        # writes scatter through the same grouped path
        fd.insert_edges([7], [9])
        assert 9 in set(fd.out_neighbors(7).tolist())
    finally:
        fp_clear("frontdesk.dispatch")
        fd.close()
