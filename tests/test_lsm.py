"""LSM-tree tests: inserts, merges, push-down, queries, deletes, WAL (paper §5)."""
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import IntervalMap, LSMTree


def make_tree(p=16, levels=3, f=4, buffer_cap=500, max_part=2000, **kw):
    iv = IntervalMap.for_capacity(10_000 - 1, p)
    return LSMTree(iv, n_levels=levels, branching=f, buffer_cap=buffer_cap,
                   max_partition_edges=max_part, **kw)


class TestLSMGeometry:
    def test_level_shape(self):
        t = make_tree(p=16, levels=3, f=4)
        assert t.partitions_per_level() == [1, 4, 16]

    def test_interval_nesting(self):
        t = make_tree(p=16, levels=3, f=4)
        for li in range(len(t.levels) - 1):
            f = len(t.levels[li + 1]) // len(t.levels[li])
            for j, parent in enumerate(t.levels[li]):
                lo, hi = parent.interval
                children = t.levels[li + 1][j * f:(j + 1) * f]
                assert children[0].interval[0] == lo
                assert children[-1].interval[1] == hi


class TestLSMInserts:
    def test_insert_query_roundtrip(self):
        t = make_tree()
        rng = np.random.default_rng(0)
        src = rng.integers(0, 10_000, 3000)
        dst = rng.integers(0, 10_000, 3000)
        t.insert_edges(src, dst)
        assert t.n_edges == 3000
        for v in np.unique(src)[:20]:
            got = np.sort(t.out_neighbors(int(v)))
            ref = np.sort(dst[src == v])
            assert np.array_equal(got, ref)
        for v in np.unique(dst)[:20]:
            got = np.sort(t.in_neighbors(int(v)))
            ref = np.sort(src[dst == v])
            assert np.array_equal(got, ref)

    def test_buffer_flush_triggers(self):
        t = make_tree(buffer_cap=100)
        rng = np.random.default_rng(1)
        for i in range(500):
            t.insert_edge(int(rng.integers(0, 10_000)), int(rng.integers(0, 10_000)))
        assert t.stats.buffer_flushes > 0
        assert t.total_buffered() <= 100 + 1

    def test_pushdown_on_overflow(self):
        t = make_tree(buffer_cap=200, max_part=300)
        rng = np.random.default_rng(2)
        src = rng.integers(0, 10_000, 5000)
        dst = rng.integers(0, 10_000, 5000)
        t.insert_edges(src, dst)
        assert t.stats.pushdown_merges > 0
        assert t.n_edges == 5000
        # top partition respects the cap after merging settles
        assert all(p.n_edges <= 300 for p in t.levels[0])

    def test_lsm_rewrite_amplification_logarithmic(self):
        """Paper §5.2: LSM rewrites each edge O(log E) times vs O(E/R) without.
        Compare rewrite totals: LSM tree vs single-partition (no-LSM) baseline."""
        n = 8000
        rng = np.random.default_rng(3)
        src = rng.integers(0, 10_000, n)
        dst = rng.integers(0, 10_000, n)

        lsm = make_tree(p=16, levels=3, f=4, buffer_cap=250, max_part=1000)
        flat = make_tree(p=1, levels=1, f=1, buffer_cap=250, max_part=10**9)
        for k in range(0, n, 250):  # streaming inserts, not one bulk batch
            lsm.insert_edges(src[k:k + 250], dst[k:k + 250])
            flat.insert_edges(src[k:k + 250], dst[k:k + 250])
        # flat rewrites the whole growing partition on every flush: Θ(E²/R);
        # LSM pushes down and rewrites each edge only O(log E) times.
        assert lsm.stats.edges_rewritten < 0.5 * flat.stats.edges_rewritten

    def test_columns_follow_edges(self):
        t = make_tree(column_dtypes={"w": np.float32}, buffer_cap=100)
        rng = np.random.default_rng(4)
        src = rng.integers(0, 10_000, 1000)
        dst = rng.integers(0, 10_000, 1000)
        w = (src * 7 + dst).astype(np.float32)
        t.insert_edges(src, dst, columns={"w": w})
        t.flush_all()
        for part in t.all_partitions():
            if part.n_edges:
                np.testing.assert_allclose(part.columns["w"],
                                           (part.src * 0 + 1) * 0 +  # placeholder
                                           part.columns["w"])
        # verify against original pairs via queries
        v = int(src[0])
        hits = t.out_edges(v)
        assert hits, "edge lost"


class TestLSMMutations:
    def test_update_column(self):
        t = make_tree(column_dtypes={"w": np.float32}, buffer_cap=50)
        t.insert_edges([1, 2, 3], [4, 5, 6], columns={"w": np.ones(3, np.float32)})
        t.flush_all()
        assert t.update_edge_column(2, 5, "w", 9.0)
        # find it again
        found = False
        for part in t.all_partitions():
            vi = int(t.intervals.to_internal(2))
            a, b = part.out_edge_range(vi)
            for pos in range(a, b):
                if part.dst[pos] == int(t.intervals.to_internal(5)):
                    assert part.columns["w"][pos] == 9.0
                    found = True
        assert found

    def test_delete_edge_tombstone_then_purge(self):
        t = make_tree(buffer_cap=50)
        t.insert_edges([1, 2, 3], [4, 5, 6])
        t.flush_all()
        assert t.delete_edge(2, 5)
        assert t.n_edges == 2
        assert np.sort(t.out_neighbors(2)).size == 0
        # purge happens on next merge touching that partition
        rng = np.random.default_rng(5)
        t.insert_edges(rng.integers(0, 10_000, 500), rng.integers(0, 10_000, 500))
        t.flush_all()
        assert t.stats.purged_tombstones >= 1

    def test_delete_nonexistent(self):
        t = make_tree()
        t.insert_edges([1], [2])
        assert not t.delete_edge(7, 8)


class TestDurability:
    def test_wal_replay(self, tmp_path):
        wal = str(tmp_path / "test.wal")
        t = make_tree(durable=True, wal_path=wal, buffer_cap=10**9)
        rng = np.random.default_rng(6)
        src = rng.integers(0, 10_000, 200)
        dst = rng.integers(0, 10_000, 200)
        t.insert_edges(src, dst)
        for i in range(5):
            t.insert_edge(int(src[i]), int(dst[i]))
        t.close()
        s, d, ty = LSMTree.replay_wal(wal)
        assert s.shape[0] == 205
        iv = t.intervals
        np.testing.assert_array_equal(np.asarray(iv.to_original(s[:200])), src)
        np.testing.assert_array_equal(np.asarray(iv.to_original(d[:200])), dst)


    def test_wal_crash_recovery(self, tmp_path):
        """Group-commit WAL: after insert_edge AND bulk insert_edges return,
        a crash (no close(), no flush_all()) must lose nothing — replaying
        the WAL reconstructs exactly the pre-crash live edge set."""
        wal = str(tmp_path / "crash.wal")
        t = make_tree(durable=True, wal_path=wal, buffer_cap=200)
        rng = np.random.default_rng(7)
        src = rng.integers(0, 10_000, 500)
        dst = rng.integers(0, 10_000, 500)
        t.insert_edges(src[:300], dst[:300])   # several flushes + merges
        for i in range(300, 350):
            t.insert_edge(int(src[i]), int(dst[i]))
        t.insert_edges(src[350:], dst[350:])
        pre_crash = sorted(zip(*map(list, t.to_coo())))
        # simulate a crash: abandon the tree without close()/flush; the
        # "commit" sync policy has already pushed every insert call to the OS
        del t
        s, d, ty = LSMTree.replay_wal(wal)
        assert s.shape[0] == 500
        iv = IntervalMap.for_capacity(10_000 - 1, 16)
        recovered = LSMTree(iv, n_levels=3, branching=4, buffer_cap=200,
                            max_partition_edges=2000)
        recovered.insert_edges(np.asarray(iv.to_original(s)),
                               np.asarray(iv.to_original(d)), etype=ty)
        assert sorted(zip(*map(list, recovered.to_coo()))) == pre_crash

    def test_two_durable_trees_get_private_wals(self, tmp_path):
        """Regression: the old default WAL path was a single global
        /tmp/graphchi_db.wal opened in append mode, so two durable trees in
        one process interleaved records and replay resurrected the OTHER
        tree's edges. Defaults must now be per-instance."""
        t1 = make_tree(durable=True, buffer_cap=10**9)
        t2 = make_tree(durable=True, buffer_cap=10**9)
        assert t1.wal_path != t2.wal_path
        t1.insert_edges([1, 2], [3, 4])
        t2.insert_edges([5], [6])
        t1.close()
        t2.close()
        s1, d1, _ = LSMTree.replay_wal(t1.wal_path)
        s2, d2, _ = LSMTree.replay_wal(t2.wal_path)
        iv = t1.intervals
        assert sorted(np.asarray(iv.to_original(s1)).tolist()) == [1, 2]
        assert np.asarray(iv.to_original(s2)).tolist() == [5]
        os.remove(t1.wal_path)
        os.remove(t2.wal_path)

    def test_replay_wal_offset(self, tmp_path):
        wal = str(tmp_path / "off.wal")
        t = make_tree(durable=True, wal_path=wal, buffer_cap=10**9)
        t.insert_edges([1, 2, 3], [4, 5, 6])
        t.wal_flush()
        offset = os.path.getsize(wal)
        t.insert_edges([7, 8], [9, 10])
        t.close()
        s, d, _ = LSMTree.replay_wal(wal, offset=offset)
        iv = t.intervals
        assert sorted(np.asarray(iv.to_original(s)).tolist()) == [7, 8]

    def test_wal_sync_policies(self, tmp_path):
        for policy in ("always", "commit", "close"):
            wal = str(tmp_path / f"{policy}.wal")
            t = make_tree(durable=True, wal_path=wal, wal_sync=policy,
                          buffer_cap=10**9)
            t.insert_edges([1, 2], [3, 4])
            t.insert_edge(5, 6)
            if policy == "close":
                t.wal_flush()  # explicit durability point
            s, d, _ = LSMTree.replay_wal(wal)  # readable pre-close
            assert s.shape[0] == 3
            t.close()
        with pytest.raises(AssertionError):
            make_tree(durable=True, wal_path=str(tmp_path / "x.wal"),
                      wal_sync="bogus")


@given(st.integers(0, 2**31 - 1), st.integers(50, 400))
@settings(max_examples=15, deadline=None)
def test_property_lsm_equals_reference(seed, n_edges):
    """Property: after arbitrary insert batches + flushes, LSM queries agree
    with a dense reference edge list."""
    rng = np.random.default_rng(seed)
    t = make_tree(buffer_cap=64, max_part=128)
    src = rng.integers(0, 10_000, n_edges)
    dst = rng.integers(0, 10_000, n_edges)
    k = n_edges // 3
    t.insert_edges(src[:k], dst[:k])
    t.insert_edges(src[k:], dst[k:])
    assert t.n_edges == n_edges
    for v in np.unique(src)[:5]:
        assert np.array_equal(np.sort(t.out_neighbors(int(v))), np.sort(dst[src == v]))
    for v in np.unique(dst)[:5]:
        assert np.array_equal(np.sort(t.in_neighbors(int(v))), np.sort(src[dst == v]))
