"""Linear-merge write-path properties (ISSUE 2, DESIGN.md §6).

The invariant behind every test: a partition produced by any chain of
incremental `merge_sorted_runs`-based merges must be bitwise identical to a
from-scratch `build_partition` re-sort of the same edges — src/dst/etype,
the CSR/CSC index arrays, and the attribute columns.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import IntervalMap, LSMTree
from repro.core.pal import (
    build_partition,
    merge_runs,
    merge_runs_into_partition,
    merge_sorted_runs,
    run_from_arrays,
    run_from_partition,
    sorted_run_index,
)

INDEX_FIELDS = ("src", "dst", "etype", "src_vertices", "src_ptr",
                "dst_perm", "dst_vertices", "dst_ptr")


def assert_partition_bitwise(got, ref, context=""):
    for name in INDEX_FIELDS:
        a, b = getattr(got, name), getattr(ref, name)
        assert a.dtype == b.dtype, (context, name, a.dtype, b.dtype)
        assert np.array_equal(a, b), (context, name)
    assert got.columns.keys() == ref.columns.keys(), context
    for k in ref.columns:
        assert np.array_equal(got.columns[k], ref.columns[k]), (context, k)


class TestMergePrimitives:
    def test_merge_sorted_runs_equals_lexsort(self):
        rng = np.random.default_rng(0)
        for trial in range(50):
            n_a, n_b = rng.integers(0, 40, 2)
            kb = int(rng.integers(2, 50))
            a = np.sort(rng.integers(0, kb * kb, n_a))
            b = np.sort(rng.integers(0, kb * kb, n_b))
            a_s, a_d = a // kb, a % kb
            b_s, b_d = b // kb, b % kb
            pos_a, pos_b = merge_sorted_runs(a_s, a_d, b_s, b_d, kb)
            merged = np.empty(n_a + n_b, np.int64)
            merged[pos_a] = a
            merged[pos_b] = b
            ref = np.sort(np.concatenate([a, b]))
            assert np.array_equal(merged, ref), trial
            # stability: equal keys keep A (old) before B (new)
            order = np.empty(n_a + n_b, np.int64)
            order[pos_a] = np.arange(n_a)
            order[pos_b] = n_a + np.arange(n_b)
            ref_order = np.argsort(np.concatenate([a, b]), kind="stable")
            assert np.array_equal(order, ref_order), trial

    def test_sorted_run_index_equals_unique(self):
        rng = np.random.default_rng(1)
        for n in (0, 1, 5, 1000):
            vals = np.sort(rng.integers(0, 50, n))
            vertices, ptr = sorted_run_index(vals)
            uv, first = np.unique(vals, return_index=True)
            ref_ptr = np.concatenate([first, [n]]).astype(np.int64)
            assert np.array_equal(vertices, uv)
            assert np.array_equal(ptr, ref_ptr)

    def test_merge_into_partition_bitwise_vs_rebuild(self):
        rng = np.random.default_rng(2)
        for trial in range(50):
            n_a, n_b = rng.integers(0, 50, 2)
            kb = int(rng.integers(4, 64))
            a_s, a_d = rng.integers(0, kb, n_a), rng.integers(0, kb, n_a)
            b_s, b_d = rng.integers(0, kb, n_b), rng.integers(0, kb, n_b)
            wa = np.arange(n_a, dtype=np.float32)
            wb = 1000 + np.arange(n_b, dtype=np.float32)
            pa = build_partition((0, kb), a_s, a_d, columns={"w": wa})
            ref = build_partition(
                (0, kb),
                np.concatenate([pa.src, np.asarray(b_s, np.int64)]),
                np.concatenate([pa.dst, np.asarray(b_d, np.int64)]),
                None,
                {"w": np.concatenate([pa.columns["w"], wb])})
            got = merge_runs_into_partition(
                (0, kb), run_from_partition(pa),
                run_from_arrays(b_s, b_d, columns={"w": wb}, key_bound=kb),
                kb, {"w": np.float32})
            assert_partition_bitwise(got, ref, f"trial {trial}")

    def test_merge_with_tombstones_purges(self):
        rng = np.random.default_rng(3)
        kb = 32
        a_s, a_d = rng.integers(0, kb, 40), rng.integers(0, kb, 40)
        pa = build_partition((0, kb), a_s, a_d)
        dead_pos = rng.choice(40, size=10, replace=False)
        pa.tombstone(dead_pos)
        live = ~pa.dead
        b_s, b_d = rng.integers(0, kb, 15), rng.integers(0, kb, 15)
        ref = build_partition(
            (0, kb),
            np.concatenate([pa.src[live], np.asarray(b_s, np.int64)]),
            np.concatenate([pa.dst[live], np.asarray(b_d, np.int64)]))
        got = merge_runs_into_partition(
            (0, kb), run_from_partition(pa, live=live),
            run_from_arrays(b_s, b_d, key_bound=kb), kb)
        assert_partition_bitwise(got, ref, "tombstones")

    def test_merge_runs_matches_partition_build(self):
        """merge_runs (the overflow short-circuit) and
        merge_runs_into_partition agree on the same inputs."""
        rng = np.random.default_rng(4)
        kb = 40
        a_s, a_d = rng.integers(0, kb, 30), rng.integers(0, kb, 30)
        b_s, b_d = rng.integers(0, kb, 20), rng.integers(0, kb, 20)
        pa = build_partition((0, kb), a_s, a_d)
        b = run_from_arrays(b_s, b_d, key_bound=kb)
        part = merge_runs_into_partition((0, kb), run_from_partition(pa), b, kb)
        combined = merge_runs(run_from_partition(pa), b, kb)
        assert np.array_equal(combined.src, part.src)
        assert np.array_equal(combined.dst, part.dst)
        assert np.array_equal(combined.etype, part.etype)
        assert np.array_equal(combined.dst_order, part.dst_perm)


def _reference_edges(tree):
    s, d = tree.to_coo()
    return sorted(zip(s.tolist(), d.tolist()))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_property_lsm_partitions_equal_scratch_rebuild(seed):
    """Across random insert/delete/flush interleavings, every partition the
    incremental merge path produced is bitwise identical to a from-scratch
    build_partition of its own edges — and the store's live edge set
    matches a dense reference."""
    rng = np.random.default_rng(seed)
    iv = IntervalMap.for_capacity(10_000 - 1, 16)
    t = LSMTree(iv, n_levels=3, branching=4,
                buffer_cap=int(rng.integers(32, 200)),
                max_partition_edges=int(rng.integers(150, 600)),
                column_dtypes={"w": np.float32})
    ref = []
    serial = 0
    for _ in range(int(rng.integers(2, 7))):
        op = rng.integers(0, 10)
        if op < 6:  # bulk insert
            n = int(rng.integers(1, 400))
            s = rng.integers(0, 10_000, n)
            d = rng.integers(0, 10_000, n)
            w = (serial + np.arange(n)).astype(np.float32)
            serial += n
            t.insert_edges(s, d, columns={"w": w})
            ref += list(zip(s.tolist(), d.tolist()))
        elif op < 8:  # single inserts
            for _ in range(int(rng.integers(1, 30))):
                s, d = int(rng.integers(0, 10_000)), int(rng.integers(0, 10_000))
                t.insert_edge(s, d, w=float(serial))
                serial += 1
                ref.append((s, d))
        elif op == 8 and ref:  # delete an existing edge everywhere
            s, d = ref[int(rng.integers(0, len(ref)))]
            if t.delete_edge(s, d):
                ref = [e for e in ref if e != (s, d)]
        else:
            t.flush_all()
    assert _reference_edges(t) == sorted(ref)
    # the write-path invariant, partition by partition
    for part in t.all_partitions():
        rebuilt = build_partition(
            part.interval, part.src.copy(), part.dst.copy(),
            part.etype.copy(), {k: v.copy() for k, v in part.columns.items()})
        assert_partition_bitwise(part, rebuilt)


@given(st.integers(0, 2**31 - 1), st.integers(10, 300))
@settings(max_examples=15, deadline=None)
def test_property_columns_track_edges_through_merges(seed, n_edges):
    """Attribute columns stay positionally attached to their edges through
    arbitrary flush/push-down chains."""
    rng = np.random.default_rng(seed)
    iv = IntervalMap.for_capacity(2_000 - 1, 16)
    t = LSMTree(iv, n_levels=3, branching=4, buffer_cap=48,
                max_partition_edges=128, column_dtypes={"w": np.float64})
    s = rng.integers(0, 2_000, n_edges)
    d = rng.integers(0, 2_000, n_edges)
    # value derivable from the edge itself (partitions hold internal IDs)
    w = (np.asarray(iv.to_internal(s)) * 4099.0 + np.asarray(iv.to_internal(d)))
    k = n_edges // 2
    t.insert_edges(s[:k], d[:k], columns={"w": w[:k]})
    t.insert_edges(s[k:], d[k:], columns={"w": w[k:]})
    t.flush_all()
    for part in t.all_partitions():
        if part.n_edges:
            np.testing.assert_array_equal(
                part.columns["w"], part.src * 4099.0 + part.dst)
