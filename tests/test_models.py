"""Model-layer tests: transformer (dense/MoE/decode), GNNs (incl. exact
equivariance for EquiformerV2), BERT4Rec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import bert4rec, transformer
from repro.models.gnn import equiformer_v2, gin, meshgraphnet, pna, wigner


def tiny_lm_cfg(**kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=97, q_chunk=8, kv_chunk=8,
                compute_dtype=jnp.float32)
    base.update(kw)
    return transformer.TransformerConfig(**base)


def rand_rot(key):
    A = jax.random.normal(key, (3, 3))
    Q, _ = jnp.linalg.qr(A)
    return Q * jnp.sign(jnp.linalg.det(Q))


def graph_batch(key, n=40, e=160, d_in=16, with_pos=False, n_species=8):
    ks = jax.random.split(key, 6)
    b = {
        "x": jax.random.normal(ks[0], (n, d_in)),
        "src": jax.random.randint(ks[1], (e,), 0, n),
        "dst": jax.random.randint(ks[2], (e,), 0, n),
        "edge_mask": jnp.ones((e,), bool).at[-5:].set(False),
        "node_mask": jnp.ones((n,), bool),
        "edge_attr": jax.random.normal(ks[3], (e, 8)),
    }
    if with_pos:
        b["pos"] = jax.random.normal(ks[4], (n, 3))
        b["species"] = jax.random.randint(ks[5], (n,), 0, n_species)
    return b


class TestTransformer:
    def test_train_step_dense(self):
        cfg = tiny_lm_cfg(qk_norm=True)
        key = jax.random.PRNGKey(0)
        p = transformer.init_params(key, cfg)
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        loss, grads = jax.value_and_grad(transformer.loss_fn)(p, batch, cfg)
        assert np.isfinite(float(loss))
        leaf_sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads)
        assert np.isfinite(float(jax.tree.reduce(lambda a, b: a + b, leaf_sq)))

    def test_train_step_moe(self):
        cfg = tiny_lm_cfg(moe=transformer.MoEConfig(n_experts=4, top_k=2,
                                                    d_ff_expert=64))
        key = jax.random.PRNGKey(1)
        p = transformer.init_params(key, cfg)
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
        loss, grads = jax.value_and_grad(transformer.loss_fn)(
            p, {"tokens": toks, "labels": toks}, cfg)
        assert np.isfinite(float(loss))
        # router grads flow
        rg = grads["layers"]["mlp"]["router"]
        assert float(jnp.abs(rg).sum()) > 0

    def test_decode_matches_forward_fp32(self):
        cfg = tiny_lm_cfg(qk_norm=True)
        key = jax.random.PRNGKey(2)
        p = transformer.init_params(key, cfg)
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
        full, _ = transformer.forward(p, toks, cfg)
        lpre, cache = transformer.prefill(p, toks[:, :8], cfg, max_seq=16,
                                          cache_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lpre), np.asarray(full[:, 7]),
                                   rtol=1e-4, atol=1e-4)
        pos = jnp.int32(8)
        for i in range(8, 12):
            lg, cache = transformer.decode_step(p, cache, toks[:, i:i + 1], pos, cfg)
            np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, i]),
                                       rtol=1e-4, atol=1e-4)
            pos = pos + 1

    def test_blockwise_attention_vs_direct(self):
        key = jax.random.PRNGKey(3)
        B, S, H, Hkv, D = 2, 32, 4, 2, 16
        q = jax.random.normal(key, (B, S, H, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
        out = transformer.blockwise_attention(q, k, v, causal=True,
                                              q_chunk=8, kv_chunk=8)
        # direct reference
        G = H // Hkv
        qg = q.reshape(B, S, Hkv, G, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * D ** -0.5
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, -1)
        ref = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, S, H, D)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_moe_all_tokens_routed_when_capacity_ample(self):
        cfg = tiny_lm_cfg(moe=transformer.MoEConfig(
            n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=8.0))
        key = jax.random.PRNGKey(4)
        p = transformer.init_params(key, cfg)
        x = jax.random.normal(key, (2, 8, cfg.d_model))
        # moe params are stacked over layers; take layer 0
        lp = jax.tree.map(lambda w: w[0], p["layers"]["mlp"])
        out, aux = transformer.moe_mlp(lp, x.astype(cfg.compute_dtype), cfg)
        assert out.shape == x.shape
        assert np.isfinite(np.asarray(out)).all()
        assert float(aux) >= 0

    def test_param_count_formula(self):
        cfg = tiny_lm_cfg()
        p = transformer.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(p))
        assert actual == cfg.n_params

    def test_logical_axes_tree_matches_params(self):
        cfg = tiny_lm_cfg(qk_norm=True,
                          moe=transformer.MoEConfig(4, 2, 64))
        p = transformer.init_params(jax.random.PRNGKey(0), cfg)
        axes = transformer.param_logical_axes(cfg)
        jax.tree.map(lambda arr, ax: None if len(ax) == arr.ndim else
                     (_ for _ in ()).throw(AssertionError(f"{arr.shape} vs {ax}")),
                     p, axes, is_leaf=lambda x: isinstance(x, tuple) and all(
                         isinstance(a, (str, type(None))) for a in x))


class TestGNNs:
    def test_pna_forward(self):
        cfg = pna.PNAConfig(n_layers=2, d_hidden=24, d_in=16, n_classes=5)
        key = jax.random.PRNGKey(0)
        p = pna.init_params(key, cfg)
        out = pna.forward(p, graph_batch(key), cfg)
        assert out.shape == (40, 5)
        assert np.isfinite(np.asarray(out)).all()

    def test_pna_grad(self):
        cfg = pna.PNAConfig(n_layers=2, d_hidden=24, d_in=16, n_classes=5)
        key = jax.random.PRNGKey(0)
        p = pna.init_params(key, cfg)
        b = graph_batch(key)

        def loss(p):
            return (pna.forward(p, b, cfg) ** 2).mean()
        g = jax.grad(loss)(p)
        assert np.isfinite(float(jax.tree.reduce(
            lambda a, x: a + jnp.abs(x).sum(), g, 0.0)))

    def test_gin_forward_graph_readout(self):
        cfg = gin.GINConfig(n_layers=3, d_hidden=16, d_in=16, n_classes=4)
        key = jax.random.PRNGKey(1)
        p = gin.init_params(key, cfg)
        out = gin.forward(p, graph_batch(key), cfg)
        assert out.shape == (1, 4)
        assert np.isfinite(np.asarray(out)).all()

    def test_gin_sum_aggregation_counts_multiplicity(self):
        """GIN must distinguish multisets: double edges change the output."""
        cfg = gin.GINConfig(n_layers=1, d_hidden=8, d_in=4, n_classes=2,
                            readout="node")
        key = jax.random.PRNGKey(2)
        p = gin.init_params(key, cfg)
        b1 = {
            "x": jnp.ones((3, 4)), "src": jnp.asarray([0, 1]),
            "dst": jnp.asarray([2, 2]), "edge_mask": jnp.ones(2, bool),
            "node_mask": jnp.ones(3, bool),
        }
        b2 = dict(b1, src=jnp.asarray([0, 0]), dst=jnp.asarray([2, 2]))
        o1 = gin.forward(p, b1, cfg)
        o2 = gin.forward(p, b2, cfg)
        # same multiset here (features equal) -> equal; now make features differ
        b1d = dict(b1, x=b1["x"].at[1].set(2.0))
        b2d = dict(b2, x=b1["x"].at[1].set(2.0))
        o1d = gin.forward(p, b1d, cfg)
        o2d = gin.forward(p, b2d, cfg)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)
        assert np.abs(np.asarray(o1d[2]) - np.asarray(o2d[2])).max() > 1e-6

    def test_meshgraphnet_forward(self):
        cfg = meshgraphnet.MeshGraphNetConfig(n_layers=3, d_hidden=32,
                                              d_node_in=16, d_edge_in=8, d_out=3)
        key = jax.random.PRNGKey(3)
        p = meshgraphnet.init_params(key, cfg)
        out = meshgraphnet.forward(p, graph_batch(key), cfg)
        assert out.shape == (40, 3)
        assert np.isfinite(np.asarray(out)).all()

    def test_equiformer_forward(self):
        cfg = equiformer_v2.EquiformerV2Config(n_layers=2, d_hidden=16,
                                               l_max=3, m_max=2, n_heads=4)
        key = jax.random.PRNGKey(4)
        p = equiformer_v2.init_params(key, cfg)
        b = graph_batch(key, n=12, e=40, with_pos=True)
        out = equiformer_v2.forward(p, b, cfg)
        assert out.shape == (12, 1)
        assert np.isfinite(np.asarray(out)).all()

    def test_equiformer_rotation_invariance(self):
        """The invariant output must be exactly invariant under global
        rotation of the input coordinates — the core eSCN property."""
        cfg = equiformer_v2.EquiformerV2Config(n_layers=2, d_hidden=16,
                                               l_max=4, m_max=2, n_heads=4)
        key = jax.random.PRNGKey(5)
        p = equiformer_v2.init_params(key, cfg)
        b = graph_batch(key, n=10, e=30, with_pos=True)
        out1 = equiformer_v2.forward(p, b, cfg)
        R = rand_rot(jax.random.PRNGKey(77))
        b_rot = dict(b, pos=b["pos"] @ R.T)
        out2 = equiformer_v2.forward(p, b_rot, cfg)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=2e-4, atol=2e-4)

    def test_equiformer_translation_invariance(self):
        cfg = equiformer_v2.EquiformerV2Config(n_layers=1, d_hidden=16,
                                               l_max=2, m_max=1, n_heads=4)
        key = jax.random.PRNGKey(6)
        p = equiformer_v2.init_params(key, cfg)
        b = graph_batch(key, n=10, e=30, with_pos=True)
        out1 = equiformer_v2.forward(p, b, cfg)
        b_t = dict(b, pos=b["pos"] + jnp.asarray([1.0, -2.0, 0.5]))
        out2 = equiformer_v2.forward(p, b_t, cfg)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-5, atol=1e-5)


class TestWigner:
    @pytest.mark.parametrize("l_max", [2, 4, 6])
    def test_homomorphism_and_orthogonality(self, l_max):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        R1, R2 = rand_rot(k1), rand_rot(k2)
        M1 = wigner.wigner_rotations(R1, l_max)
        M2 = wigner.wigner_rotations(R2, l_max)
        M12 = wigner.wigner_rotations(R1 @ R2, l_max)
        for l in range(l_max + 1):
            np.testing.assert_allclose(np.asarray(M1[l] @ M2[l]),
                                       np.asarray(M12[l]), atol=2e-5)
            np.testing.assert_allclose(np.asarray(M1[l] @ M1[l].T),
                                       np.eye(2 * l + 1), atol=2e-5)

    def test_l2_against_explicit_sh(self):
        R = rand_rot(jax.random.PRNGKey(9))
        M = wigner.wigner_rotations(R, 2)[2]

        def Y2(v):
            x, y, z = v
            s15 = jnp.sqrt(15.0)
            return jnp.stack([s15 * x * y, s15 * y * z,
                              jnp.sqrt(5.0) / 2 * (3 * z * z - 1),
                              s15 * x * z, s15 / 2 * (x * x - y * y)])
        v = jax.random.normal(jax.random.PRNGKey(10), (3,))
        v = v / jnp.linalg.norm(v)
        np.testing.assert_allclose(np.asarray(Y2(R @ v)),
                                   np.asarray(M @ Y2(v)), atol=1e-5)

    def test_rotation_to_z(self):
        d = jax.random.normal(jax.random.PRNGKey(11), (20, 3))
        R = wigner.rotation_to_z(d)
        dn = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
        out = jnp.einsum("eij,ej->ei", R, dn)
        np.testing.assert_allclose(np.asarray(out),
                                   np.tile([0.0, 0.0, 1.0], (20, 1)), atol=1e-5)
        # determinant +1 (proper rotations)
        np.testing.assert_allclose(np.asarray(jnp.linalg.det(R)), 1.0, atol=1e-5)


class TestBert4Rec:
    def test_masked_lm(self):
        cfg = bert4rec.Bert4RecConfig(n_items=50, embed_dim=16, n_blocks=2,
                                      n_heads=2, seq_len=12)
        key = jax.random.PRNGKey(0)
        p = bert4rec.init_params(key, cfg)
        seq = jax.random.randint(key, (4, 12), 1, cfg.n_items + 1)
        mpos = jnp.full((4, 2), 5, jnp.int32).at[:, 1].set(7)
        labels = jnp.stack([seq[:, 5], seq[:, 7]], axis=1)
        seq = seq.at[:, 5].set(cfg.vocab - 1).at[:, 7].set(cfg.vocab - 1)
        batch = {"item_seq": seq, "masked_positions": mpos, "labels": labels}
        loss, g = jax.value_and_grad(bert4rec.masked_lm_loss)(p, batch, cfg)
        assert np.isfinite(float(loss))
        assert float(jnp.abs(g["item_embed"]).sum()) > 0

    def test_masked_lm_chunked_logsumexp_exact(self):
        """Streaming CE must equal the dense softmax CE."""
        cfg = bert4rec.Bert4RecConfig(n_items=50, embed_dim=16, n_blocks=1,
                                      n_heads=2, seq_len=12)
        key = jax.random.PRNGKey(3)
        p = bert4rec.init_params(key, cfg)
        seq = jax.random.randint(key, (4, 12), 1, cfg.n_items + 1)
        mpos = jnp.full((4, 1), 5, jnp.int32)
        labels = seq[:, 5:6]
        seq = seq.at[:, 5].set(cfg.vocab - 1)
        batch = {"item_seq": seq, "masked_positions": mpos, "labels": labels}
        l1 = bert4rec.masked_lm_loss(p, batch, cfg, vocab_chunk=7)
        # dense reference
        reps = bert4rec.encode(p, seq, cfg)
        logits = reps[:, 5] @ p["item_embed"].T + p["out_bias"]
        logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab,
                           logits, -jnp.inf)
        logz = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, labels, axis=-1)[:, 0]
        ref = (logz - gold).mean()
        np.testing.assert_allclose(float(l1), float(ref), rtol=1e-5)

    def test_scoring_consistency(self):
        cfg = bert4rec.Bert4RecConfig(n_items=50, embed_dim=16, n_blocks=1,
                                      n_heads=2, seq_len=8)
        key = jax.random.PRNGKey(1)
        p = bert4rec.init_params(key, cfg)
        seq = jax.random.randint(key, (2, 8), 1, cfg.n_items + 1)
        all_scores = bert4rec.score_all_items(p, seq, cfg)
        cand = jnp.asarray([3, 17, 42])
        cand_scores = bert4rec.score_candidates(p, seq, cand, cfg)
        np.testing.assert_allclose(np.asarray(cand_scores),
                                   np.asarray(all_scores[:, cand]),
                                   rtol=1e-5, atol=1e-5)

    def test_padding_masked_out(self):
        cfg = bert4rec.Bert4RecConfig(n_items=50, embed_dim=16, n_blocks=1,
                                      n_heads=2, seq_len=8)
        p = bert4rec.init_params(jax.random.PRNGKey(2), cfg)
        seq = jnp.asarray([[1, 2, 3, 4, 0, 0, 0, 5]])
        seq2 = jnp.asarray([[1, 2, 3, 4, 9, 9, 9, 5]])  # different pads->items
        r1 = bert4rec.encode(p, seq, cfg)
        r2 = bert4rec.encode(p, seq2, cfg)
        # non-pad positions must ignore pad slots in seq1
        assert np.abs(np.asarray(r1[0, 0]) - np.asarray(r2[0, 0])).max() > 0
        seq3 = jnp.asarray([[1, 2, 3, 4, 0, 0, 0, 5]])
        r3 = bert4rec.encode(p, seq3, cfg)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r3))
