"""Multi-hop operator tests (ISSUE 6): columnar 2-hop / triangle /
filtered-traversal operators vs naive per-hop references, on messy live LSM
state (buffers + tombstones), lock-free ManifestView epoch snapshots, the
dense Pallas plan path, and a reopened on-disk GraphDB."""
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (
    EdgePredicate,
    GraphDB,
    GraphPAL,
    IntervalMap,
    LSMTree,
    as_engine,
    bfs,
    bfs_perhop,
    dedup_frontier,
    friends_of_friends,
    friends_of_friends_perhop,
    khop,
    shortest_path,
    triangle_count,
    two_hop_counts,
)
from repro.core import multihop as mh


# ---------------------------------------------------------------------------
# Naive per-hop references (pure-python adjacency sets)
# ---------------------------------------------------------------------------
def adjacency(g):
    """Live adjacency sets in original ids, straight from to_coo()."""
    so, do = as_engine(g).to_coo()
    out_adj, in_adj, eset = {}, {}, set()
    for a, b in zip(np.asarray(so).tolist(), np.asarray(do).tolist()):
        out_adj.setdefault(a, set()).add(b)
        in_adj.setdefault(b, set()).add(a)
        eset.add((a, b))
    return out_adj, in_adj, eset


def naive_two_hop(out_adj, v, max_friends=None):
    """(ids, counts) per the per-hop FoF semantics: distinct middles,
    sorted-first-max_friends truncation, seed+friends excluded."""
    friends = sorted(out_adj.get(v, ()))
    if max_friends is not None:
        friends = friends[:max_friends]
    cnt = {}
    for u in friends:
        for w in out_adj.get(u, ()):
            cnt[w] = cnt.get(w, 0) + 1
    # only the (possibly truncated) friend set is excluded — exactly the
    # per-hop `setdiff1d(fof, [friends..., v])` semantics
    for w in list(cnt):
        if w == v or w in set(friends):
            del cnt[w]
    ids = sorted(cnt)
    return (np.asarray(ids, np.int64),
            np.asarray([cnt[w] for w in ids], np.int64))


def naive_triangles(out_adj, in_adj, eset):
    return sum(1 for v in set(in_adj) & set(out_adj)
               for u in in_adj[v] for w in out_adj[v] if (u, w) in eset)


def naive_filtered_khop(fadj, seeds, k):
    vis = set(seeds)
    lev = set(seeds)
    levels = [sorted(lev)]
    for _ in range(k):
        nxt = set()
        for u in lev:
            nxt |= fadj.get(u, set())
        fresh = nxt - vis
        if not fresh:
            break
        vis |= fresh
        levels.append(sorted(fresh))
        lev = fresh
    return levels, sorted(vis)


def build_messy_lsm(n, e, seed, n_deletes=0, columns=None, etype=None):
    """Live LSM with flushed levels, tombstones, and a still-buffered tail."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    iv = IntervalMap.for_capacity(n - 1, 16)
    dtypes = {k: v.dtype for k, v in (columns or {}).items()} or None
    t = LSMTree(iv, n_levels=3, branching=4, buffer_cap=max(60, e // 8),
                max_partition_edges=max(100, e // 4), column_dtypes=dtypes)
    k = e - max(1, e // 10)

    def sl(a, b):
        cols = {key: v[a:b] for key, v in (columns or {}).items()}
        et = None if etype is None else etype[a:b]
        return cols, et

    cols, et = sl(0, k)
    t.insert_edges(src[:k], dst[:k], etype=et, columns=cols)
    cols, et = sl(k, e)
    t.insert_edges(src[k:], dst[k:], etype=et, columns=cols)
    for i in rng.choice(k, size=min(n_deletes, k), replace=False):
        t.delete_edge(int(src[i]), int(dst[i]))
    return t


def assert_two_hop_equal(res, seeds, out_adj, max_friends=None):
    for i, v in enumerate(np.asarray(seeds).tolist()):
        ids, counts = naive_two_hop(out_adj, v, max_friends)
        sl = res.slice_of(i)
        assert np.array_equal(res.ids[sl], ids), v
        assert np.array_equal(res.counts[sl], counts), v


# ---------------------------------------------------------------------------
# Property tests: random live stores vs the naive reference
# ---------------------------------------------------------------------------
class TestPropertyVsNaive:
    @given(st.integers(0, 10_000), st.integers(20, 400), st.integers(0, 40),
           st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_two_hop_counts_matches_naive(self, seed, e, n_deletes, trunc):
        n = 120
        t = build_messy_lsm(n, e, seed, n_deletes)
        out_adj, _, _ = adjacency(t)
        rng = np.random.default_rng(seed)
        seeds = rng.integers(0, n, 17).astype(np.int64)  # dups allowed
        mf = 3 if trunc else None
        res = two_hop_counts(t, seeds, max_friends=mf)
        assert_two_hop_equal(res, seeds, out_adj, mf)

    @given(st.integers(0, 10_000), st.integers(20, 400), st.integers(0, 40))
    @settings(max_examples=25, deadline=None)
    def test_triangle_count_matches_naive(self, seed, e, n_deletes):
        n = 100
        t = build_messy_lsm(n, e, seed, n_deletes)
        out_adj, in_adj, eset = adjacency(t)
        want = naive_triangles(out_adj, in_adj, eset)
        assert triangle_count(t) == want
        # chunked wedge budget must not change the count
        assert triangle_count(t, wedge_budget=7) == want

    @given(st.integers(0, 10_000), st.integers(20, 300))
    @settings(max_examples=20, deadline=None)
    def test_filtered_traversal_matches_naive(self, seed, e):
        n = 90
        rng = np.random.default_rng(seed)
        w = rng.integers(0, 10, e).astype(np.float32)
        et = rng.integers(0, 3, e).astype(np.int8)
        t = build_messy_lsm(n, e, seed, columns={"w": w}, etype=et)
        pred = EdgePredicate(etype=1, column="w", op="<=", value=5.0)
        batch = as_engine(t).edge_columns_batch(np.arange(n), names=["w"])
        fadj = {}
        for s, d, ww, ee in zip(batch.src.tolist(), batch.dst.tolist(),
                                batch.columns["w"].tolist(),
                                batch.etype.tolist()):
            if ee == 1 and ww <= 5.0:
                fadj.setdefault(s, set()).add(d)
        seeds = [int(rng.integers(0, n))]
        res = khop(t, seeds, 3, predicate=pred)
        levels, visited = naive_filtered_khop(fadj, seeds, 3)
        assert len(res.levels) == len(levels)
        for got, want in zip(res.levels, levels):
            assert got.tolist() == want
        assert res.visited.tolist() == visited

    @given(st.integers(0, 10_000), st.integers(20, 300), st.integers(0, 30))
    @settings(max_examples=20, deadline=None)
    def test_dense_paths_bitwise_equal_sparse(self, seed, e, n_deletes):
        n = 110
        t = build_messy_lsm(n, e, seed, n_deletes)
        rng = np.random.default_rng(seed)
        seeds = np.unique(rng.integers(0, n, 9))
        sparse = two_hop_counts(t, seeds)
        dense = two_hop_counts(t, seeds, dense="kernel")
        assert np.array_equal(sparse.offsets, dense.offsets)
        assert np.array_equal(sparse.ids, dense.ids)
        assert np.array_equal(sparse.counts, dense.counts)
        s0 = [int(seeds[0])]
        base = khop(t, s0, 3, dense="never")
        for mode in ("kernel", "stream"):
            other = khop(t, s0, 3, dense=mode)
            assert len(base.levels) == len(other.levels)
            for a, b in zip(base.levels, other.levels):
                assert np.array_equal(a, b)
            assert np.array_equal(base.visited, other.visited)

    @given(st.integers(0, 10_000), st.integers(20, 300))
    @settings(max_examples=20, deadline=None)
    def test_query_facades_match_perhop(self, seed, e):
        n = 100
        t = build_messy_lsm(n, e, seed, n_deletes=10)
        rng = np.random.default_rng(seed)
        for v in rng.integers(0, n, 4).tolist():
            assert np.array_equal(friends_of_friends(t, v),
                                  friends_of_friends_perhop(t, v))
            assert np.array_equal(friends_of_friends(t, v, max_friends=2),
                                  friends_of_friends_perhop(t, v, max_friends=2))
            assert bfs(t, v, max_depth=4) == bfs_perhop(t, v, max_depth=4)
        s, d = int(rng.integers(0, n)), int(rng.integers(0, n))
        # the columnar two-sided meet takes the true minimum: oracle is
        # one-sided BFS, not the first-meet per-hop baseline
        want = bfs_perhop(t, s, max_depth=4).get(d)
        assert shortest_path(t, s, d, max_depth=4) == want


# ---------------------------------------------------------------------------
# Store-generality: epoch views and a reopened on-disk GraphDB
# ---------------------------------------------------------------------------
class TestAcrossStores:
    def test_manifest_view_identical_to_live(self):
        t = build_messy_lsm(300, 2000, seed=3, n_deletes=60)
        seeds = np.unique(np.random.default_rng(3).integers(0, 300, 40))
        live = two_hop_counts(t, seeds)
        with t.read_view() as view:
            pinned = two_hop_counts(view, seeds)
            assert np.array_equal(live.offsets, pinned.offsets)
            assert np.array_equal(live.ids, pinned.ids)
            assert np.array_equal(live.counts, pinned.counts)
            assert triangle_count(view) == triangle_count(t)
            # mutate the live store: the pinned view must not move
            t.insert_edges(np.arange(50), np.arange(1, 51))
            again = two_hop_counts(view, seeds)
            assert np.array_equal(pinned.ids, again.ids)
            assert np.array_equal(pinned.counts, again.counts)
        # the LIVE store sees the mutation (fresh cache token -> no stale
        # plan reuse)
        after_sparse = two_hop_counts(t, seeds)
        after_dense = two_hop_counts(t, seeds, dense="kernel")
        assert np.array_equal(after_sparse.ids, after_dense.ids)
        assert np.array_equal(after_sparse.counts, after_dense.counts)

    def test_reopened_graphdb_matches_prior_answers(self, tmp_path):
        rng = np.random.default_rng(11)
        n, e = 400, 3000
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        d = os.path.join(str(tmp_path), "db")
        db = GraphDB.create(d, max_id=n - 1, n_partitions=8, n_levels=2,
                            branching=4, buffer_cap=800,
                            max_partition_edges=1500, persist_min_edges=64)
        db.insert_edges(src[:e - 200], dst[:e - 200])
        db.checkpoint()
        db.insert_edges(src[e - 200:], dst[e - 200:])  # WAL-tail edges
        seeds = np.unique(rng.integers(0, n, 64))
        live = two_hop_counts(db, seeds)
        tri = triangle_count(db)
        out_adj, in_adj, eset = adjacency(db)
        assert tri == naive_triangles(out_adj, in_adj, eset)
        assert_two_hop_equal(live, seeds, out_adj)
        db.close()

        re_db = GraphDB.open(d)
        res = two_hop_counts(re_db, seeds)
        assert np.array_equal(res.offsets, live.offsets)
        assert np.array_equal(res.ids, live.ids)
        assert np.array_equal(res.counts, live.counts)
        assert triangle_count(re_db) == tri
        dense = two_hop_counts(re_db, seeds, dense="kernel")
        assert np.array_equal(dense.ids, live.ids)
        assert np.array_equal(dense.counts, live.counts)
        re_db.close()


# ---------------------------------------------------------------------------
# Engine primitives behind the operators
# ---------------------------------------------------------------------------
class TestEnginePrimitives:
    def test_expand_frontier_matches_grouped_batch(self):
        t = build_messy_lsm(200, 1200, seed=5, n_deletes=30)
        eng = as_engine(t)
        vs = np.unique(np.random.default_rng(5).integers(0, 200, 60))
        for direction in ("out", "in"):
            owner, nb = eng.expand_frontier(vs, direction)
            vals, offsets = (eng.out_neighbors_batch(vs) if direction == "out"
                             else eng.in_neighbors_batch(vs))
            M = np.int64(eng.n_internal_vertices)
            got = np.sort(owner * M + nb)
            want = np.sort(np.repeat(np.arange(vs.shape[0], dtype=np.int64),
                                     np.diff(offsets)) * M + vals)
            assert np.array_equal(got, want), direction

    def test_predicate_pushdown_prunes_before_gather(self):
        rng = np.random.default_rng(6)
        n, e = 150, 900
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        w = rng.normal(size=e)
        et = rng.integers(0, 2, e).astype(np.int8)
        g = GraphPAL.from_edges(src, dst, n_partitions=4, max_id=n - 1,
                                columns={"w": w}, etype=et)
        eng = as_engine(g)
        pred = EdgePredicate(etype=1, column="w", op=">", value=0.0)
        vs = np.arange(0, n, 2, dtype=np.int64)
        owner, nb = eng.expand_frontier(vs, "out", pred)
        keep = (et == 1) & (w > 0.0)
        want = sorted(zip(src[keep].tolist(), dst[keep].tolist()))
        got = sorted(zip(vs[owner].tolist(), nb.tolist()))
        want = [p for p in want if p[0] % 2 == 0]
        assert got == want

    def test_degree_batch_counts_live_multi_edges(self):
        t = build_messy_lsm(120, 700, seed=7, n_deletes=25)
        eng = as_engine(t)
        so, do = t.to_coo()
        vs = np.arange(120, dtype=np.int64)
        out_want = np.bincount(np.asarray(so), minlength=120)
        in_want = np.bincount(np.asarray(do), minlength=120)
        assert np.array_equal(eng.out_degree_batch(vs), out_want)
        assert np.array_equal(eng.in_degree_batch(vs), in_want)

    def test_dedup_frontier_degree_order(self):
        t = build_messy_lsm(100, 600, seed=8)
        eng = as_engine(t)
        ids = np.array([5, 5, 9, 3, 9, 40, 3], np.int64)
        out = dedup_frontier(eng, ids)
        assert np.array_equal(out, [3, 5, 9, 40])
        out = dedup_frontier(eng, ids, visited=np.array([9, 40]))
        assert np.array_equal(out, [3, 5])
        ordered = dedup_frontier(eng, ids, degree_order=True)
        deg = eng.out_degree_batch(ordered)
        assert np.all(np.diff(deg) <= 0)  # descending
        assert set(ordered.tolist()) == {3, 5, 9, 40}

    def test_semijoin_and_aggregate(self):
        table = np.array([2, 5, 9], np.int64)
        keys = np.array([9, 1, 5, 10, 2, 2], np.int64)
        assert mh.semijoin(keys, table).tolist() == \
            [True, False, True, False, True, True]
        assert mh.semijoin(keys, np.empty(0, np.int64)).tolist() == [False] * 6
        u, c = mh.aggregate_counts(np.array([3, 1, 3, 3, 1], np.int64))
        assert u.tolist() == [1, 3] and c.tolist() == [2, 3]


# ---------------------------------------------------------------------------
# The frontier-expansion kernel plan
# ---------------------------------------------------------------------------
class TestFrontierPlan:
    def test_virtual_rows_linear_in_edges(self):
        from repro.kernels.frontier_expand import build_frontier_plan
        rng = np.random.default_rng(9)
        # one hub: degree 5000 would make pad_to_ell allocate n*5000 slots
        src = np.concatenate([rng.integers(0, 1000, 5000),
                              rng.integers(0, 1000, 2000)])
        dst = np.concatenate([np.zeros(5000, np.int64),
                              rng.integers(0, 1000, 2000)])
        plan = build_frontier_plan(src, dst, 1000, 1000, k_slots=32)
        assert plan.idx.shape[0] <= ((plan.n_edges // 32 + 1000 + 1) // 128
                                     + 1) * 128
        assert plan.mask.sum() == plan.n_edges  # exact, no truncation

    def test_counts_match_dedup_matmul(self):
        from repro.kernels.frontier_expand import (build_frontier_plan,
                                                   frontier_expand_counts)
        rng = np.random.default_rng(10)
        n, e, B = 300, 2500, 5
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        plan = build_frontier_plan(src, dst, n, n, k_slots=8)
        x = (rng.random((n, B)) < 0.2).astype(np.float32)
        A = np.zeros((n, n), np.float32)
        A[dst, src] = 1.0  # dedup adjacency
        want = A @ x
        for use_kernel in (False, True):
            got = frontier_expand_counts(plan, x, use_kernel=use_kernel)
            assert np.array_equal(got, want), use_kernel
        from repro.kernels.frontier_expand import frontier_expand_np
        rows = frontier_expand_np(plan.idx, plan.mask, x)
        out = np.zeros((n + 1, B), np.float32)
        np.add.at(out, plan.row_dst, rows)
        assert np.array_equal(out[:n], want)

    def test_empty_plan(self):
        from repro.kernels.frontier_expand import (build_frontier_plan,
                                                   frontier_expand_counts)
        plan = build_frontier_plan(np.empty(0), np.empty(0), 10, 10)
        out = frontier_expand_counts(plan, np.ones((10, 2), np.float32))
        assert out.shape == (10, 2) and not out.any()
