"""PAL data-structure tests: construction, queries, invariants (paper §4)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import GraphPAL, IntervalMap, build_partition


def random_graph(rng, n_vertices=200, n_edges=1000):
    src = rng.integers(0, n_vertices, n_edges)
    dst = rng.integers(0, n_vertices, n_edges)
    return src, dst


class TestIntervalMap:
    def test_reversible_hash_roundtrip(self):
        iv = IntervalMap.for_capacity(10_000, 8)
        ids = np.arange(10_000)
        assert np.array_equal(iv.to_original(iv.to_internal(ids)), ids)

    @given(st.integers(1, 10**6), st.sampled_from([1, 2, 4, 8, 16, 64]))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_property(self, max_id, p):
        iv = IntervalMap.for_capacity(max_id, p)
        ids = np.unique(np.clip(np.geomspace(1, max_id, 64).astype(np.int64), 0, max_id))
        assert np.array_equal(iv.to_original(iv.to_internal(ids)), ids)

    def test_hash_balances_sequential_ids(self):
        """Paper §7.2: consecutive original IDs land in different intervals."""
        iv = IntervalMap.for_capacity(6400 - 1, 8)
        intern = iv.to_internal(np.arange(6400))
        counts = np.bincount(np.asarray(iv.interval_of(intern)), minlength=8)
        assert counts.max() - counts.min() <= 1

    def test_interval_of_matches_range(self):
        iv = IntervalMap.for_capacity(999, 4)
        for i in range(4):
            lo, hi = iv.interval_range(i)
            assert iv.interval_of(lo) == i
            assert iv.interval_of(hi - 1) == i


class TestEdgePartition:
    def test_source_sorted(self):
        rng = np.random.default_rng(0)
        src, dst = random_graph(rng)
        p = build_partition((0, 200), src, dst)
        assert np.all(np.diff(p.src) >= 0)

    def test_out_in_edges_consistent(self):
        rng = np.random.default_rng(1)
        src, dst = random_graph(rng, 50, 400)
        p = build_partition((0, 50), src, dst)
        for v in range(50):
            out_pos = p.out_edges(v)
            assert np.all(p.src[out_pos] == v)
            in_pos = p.in_edges(v)
            assert np.all(p.dst[in_pos] == v)
        # every edge found exactly once in each direction
        assert sum(len(p.out_edges(v)) for v in range(50)) == 400
        assert sum(len(p.in_edges(v)) for v in range(50)) == 400

    def test_window_contiguity(self):
        """Paper §6.1: out-edges of an interval are one contiguous run."""
        rng = np.random.default_rng(2)
        src, dst = random_graph(rng, 100, 1000)
        p = build_partition((0, 100), src, dst)
        a, b = p.window((25, 50))
        assert np.all((p.src[a:b] >= 25) & (p.src[a:b] < 50))
        outside = np.concatenate([p.src[:a], p.src[b:]])
        assert not np.any((outside >= 25) & (outside < 50))

    def test_columnar_positional_access(self):
        """Paper §4.3: edge position IS the attribute key — the column stays
        aligned with the edge through the (src, dst) sort."""
        rng = np.random.default_rng(11)
        src = rng.integers(0, 20, 100)
        dst = rng.integers(0, 20, 100)
        w = (src * 100 + dst).astype(np.float64)
        p = build_partition((0, 20), src, dst, columns={"w": w})
        np.testing.assert_allclose(p.columns["w"], p.src * 100 + p.dst)
        pos = p.in_edges(7)
        np.testing.assert_allclose(p.columns["w"][pos], p.src[pos] * 100 + 7)

    def test_edge_at_reverse_lookup(self):
        rng = np.random.default_rng(3)
        src, dst = random_graph(rng, 30, 200)
        p = build_partition((0, 30), src, dst)
        for pos in [0, 5, 57, 199]:
            s, d, t = p.edge_at(pos)
            assert d == p.dst[pos]
            assert pos in list(p.out_edges(s))

    def test_tombstones(self):
        src = np.array([0, 1, 2])
        dst = np.array([1, 2, 0])
        p = build_partition((0, 3), src, dst)
        p.tombstone(p.out_edges(1))
        assert p.n_live_edges == 2
        assert len(p.out_edges(1)) == 0


class TestGraphPAL:
    def test_each_edge_stored_once(self):
        rng = np.random.default_rng(4)
        src, dst = random_graph(rng, 300, 2000)
        g = GraphPAL.from_edges(src, dst, n_partitions=8)
        assert g.n_edges == 2000
        s2, d2 = g.to_coo()
        a = np.lexsort((dst, src))
        b = np.lexsort((d2, s2))
        assert np.array_equal(src[a], s2[b])
        assert np.array_equal(dst[a], d2[b])

    def test_neighbors_match_reference(self):
        rng = np.random.default_rng(5)
        src, dst = random_graph(rng, 100, 800)
        g = GraphPAL.from_edges(src, dst, n_partitions=4)
        for v in range(0, 100, 7):
            got = np.sort(g.out_neighbors(v))
            ref = np.sort(dst[src == v])
            assert np.array_equal(got, ref), v
            got_in = np.sort(g.in_neighbors(v))
            ref_in = np.sort(src[dst == v])
            assert np.array_equal(got_in, ref_in), v

    def test_batched_out_neighbors(self):
        rng = np.random.default_rng(6)
        src, dst = random_graph(rng, 100, 800)
        g = GraphPAL.from_edges(src, dst, n_partitions=4)
        vs = [0, 3, 99, 50]
        batched = g.out_neighbors_batch(vs)
        for v, got in zip(vs, batched):
            assert np.array_equal(np.sort(got), np.sort(dst[src == v]))

    def test_vertex_columns_positional(self):
        g = GraphPAL.from_edges([0, 1], [1, 2], n_partitions=2, max_id=9)
        g.add_vertex_column("score", np.float32)
        ids = np.array([0, 3, 7, 9])
        g.vertex_set("score", ids, np.array([1.0, 2.0, 3.0, 4.0], np.float32))
        np.testing.assert_allclose(g.vertex_get("score", ids), [1, 2, 3, 4])
        np.testing.assert_allclose(g.vertex_get("score", np.array([1, 2])), [0, 0])

    def test_hash_balances_clustered_ids(self):
        """Paper §7.2: the reversible hash spreads clustered ID ranges (e.g.
        recently-created vertices with consecutive IDs) across intervals.
        Without it, a contiguous-interval split would put them all in one
        partition. (Single ultra-hot vertices cannot be split by ANY id
        mapping — the paper's |E|/P in-degree constraint, §4.1.)"""
        rng = np.random.default_rng(7)
        n = 4096
        dst = rng.integers(0, n // 8, 20000)   # clustered low-ID destinations
        src = rng.integers(0, n, 20000)
        g = GraphPAL.from_edges(src, dst, n_partitions=8, max_id=n - 1)
        sizes = g.partition_sizes()
        assert sizes.max() < 1.2 * sizes.mean()
        # contiguous split (no hash) would have put 100% in partition 0
        naive = np.bincount(dst * 8 // n, minlength=8)
        assert naive.max() == 20000


@given(
    st.integers(2, 64),
    st.sampled_from([2, 4, 8]),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_property_roundtrip_any_graph(n_vertices, p, seed):
    """Property: PAL stores any multigraph losslessly and queries agree with
    the dense reference."""
    rng = np.random.default_rng(seed)
    n_edges = int(rng.integers(1, 200))
    src = rng.integers(0, n_vertices, n_edges)
    dst = rng.integers(0, n_vertices, n_edges)
    g = GraphPAL.from_edges(src, dst, n_partitions=p, max_id=n_vertices - 1)
    assert g.n_edges == n_edges
    v = int(rng.integers(0, n_vertices))
    assert np.array_equal(np.sort(g.out_neighbors(v)), np.sort(dst[src == v]))
    assert np.array_equal(np.sort(g.in_neighbors(v)), np.sort(src[dst == v]))
