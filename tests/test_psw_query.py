"""PSW engine + query-layer tests (paper §6, §7.4, §8.4)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    GraphPAL,
    IntervalMap,
    LSMTree,
    bfs,
    build_device_graph,
    edge_centric_sweep,
    friends_of_friends,
    pagerank_device,
    pagerank_host,
    shortest_path,
)
from repro.core.query import Frontier, traverse_out


def dense_pagerank(src, dst, n, iters=5, damping=0.85):
    """Reference PageRank on a dense edge list."""
    outdeg = np.bincount(src, minlength=n).astype(np.float64)
    r = np.ones(n)
    for _ in range(iters):
        contrib = r / np.maximum(outdeg, 1)
        acc = np.zeros(n)
        np.add.at(acc, dst, contrib[src])
        r = (1 - damping) + damping * acc
    return r


@pytest.fixture(scope="module")
def small_graph():
    rng = np.random.default_rng(42)
    n, e = 256, 2000
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    return n, src, dst


def gauss_seidel_pagerank(src, dst, n, iv, iters=5, damping=0.85):
    """Asynchronous (Gauss–Seidel by interval) reference: PSW sweeps update
    intervals in order and refresh out-edge values immediately, so interval i
    reads THIS iteration's ranks for sources in intervals < i — GraphChi's
    documented asynchronous semantics. Indexed by internal ID."""
    isrc = np.asarray(iv.to_internal(src))
    idst = np.asarray(iv.to_internal(dst))
    nn = iv.max_vertices
    outdeg = np.bincount(isrc, minlength=nn).astype(np.float64)
    r = np.ones(nn)
    for _ in range(iters):
        for i in range(iv.n_partitions):
            lo, hi = iv.interval_range(i)
            m = (idst >= lo) & (idst < hi)
            contrib = r[isrc[m]] / np.maximum(outdeg[isrc[m]], 1)
            acc = np.zeros(hi - lo)
            np.add.at(acc, idst[m] - lo, contrib)
            r[lo:hi] = (1 - damping) + damping * acc
    return r


class TestHostPSW:
    def test_pagerank_host_matches_async_reference(self, small_graph):
        n, src, dst = small_graph
        g = GraphPAL.from_edges(src, dst, n_partitions=4, max_id=n - 1)
        ranks = pagerank_host(g, n_iters=5)
        ref = gauss_seidel_pagerank(src, dst, n, g.intervals, iters=5)
        np.testing.assert_allclose(ranks, ref, rtol=1e-8)

    def test_pagerank_host_fixed_point_matches_jacobi(self, small_graph):
        """Async and sync iterations share the fixed point (paper §6.1.2)."""
        n, src, dst = small_graph
        g = GraphPAL.from_edges(src, dst, n_partitions=4, max_id=n - 1)
        ranks = pagerank_host(g, n_iters=60)
        ref = dense_pagerank(src, dst, n, iters=120)
        intern = np.asarray(g.intervals.to_internal(np.arange(n)))
        np.testing.assert_allclose(ranks[intern], ref, rtol=1e-6)

    def test_pagerank_on_lsm(self, small_graph):
        n, src, dst = small_graph
        iv = IntervalMap.for_capacity(n - 1, 8)
        t = LSMTree(iv, n_levels=2, branching=4, buffer_cap=300, max_partition_edges=600)
        t.insert_edges(src, dst)
        ranks = pagerank_host(t, n_iters=40)
        ref = dense_pagerank(src, dst, n, iters=80)
        intern = np.asarray(iv.to_internal(np.arange(n)))
        np.testing.assert_allclose(ranks[intern], ref, rtol=1e-6)

    def test_pagerank_host_leaves_columns_bitwise_unchanged(self, small_graph):
        """Regression (ISSUE 6): edge state lives in an overlay — a run must
        neither mutate existing attribute columns nor leave new keys (the
        old code wrote a 'pr' column in place)."""
        n, src, dst = small_graph
        w = (src * 13 + dst).astype(np.float32)
        g = GraphPAL.from_edges(src, dst, n_partitions=4, max_id=n - 1,
                                columns={"w": w})
        before = [(set(p.columns),
                   {k: (v.copy(), v) for k, v in p.columns.items()})
                  for p in g.partitions]
        ranks = pagerank_host(g, n_iters=3)
        assert np.isfinite(ranks).all()
        for p, (keys, snap) in zip(g.partitions, before):
            assert set(p.columns) == keys  # no 'pr' key injected
            for k, (copy, ref) in snap.items():
                assert p.columns[k] is ref  # same array object...
                assert np.array_equal(np.asarray(p.columns[k]),
                                      np.asarray(copy))  # ...bitwise intact

    def test_pagerank_host_leaves_lsm_columns_unchanged(self, small_graph):
        n, src, dst = small_graph
        iv = IntervalMap.for_capacity(n - 1, 8)
        t = LSMTree(iv, n_levels=2, branching=4, buffer_cap=300,
                    max_partition_edges=600,
                    column_dtypes={"w": np.float32})
        t.insert_edges(src, dst, columns={"w": (src + dst).astype(np.float32)})
        t.flush_all()
        before = [(set(p.columns), {k: v.copy() for k, v in p.columns.items()})
                  for p in t.all_partitions()]
        pagerank_host(t, n_iters=3)
        for p, (keys, snap) in zip(t.all_partitions(), before):
            assert set(p.columns) == keys
            for k, v in snap.items():
                assert np.array_equal(np.asarray(p.columns[k]), v)


class TestDevicePSW:
    @pytest.mark.parametrize("mode", ["dense_gather", "psw_windows"])
    def test_pagerank_device_matches_dense(self, small_graph, mode):
        n, src, dst = small_graph
        g = GraphPAL.from_edges(src, dst, n_partitions=4, max_id=n - 1)
        dg = build_device_graph(g)
        ranks = pagerank_device(dg, n_iters=4, mode=mode)
        ref = dense_pagerank(src, dst, n, iters=4)
        intern = np.asarray(g.intervals.to_internal(np.arange(n)))
        got = np.asarray(ranks).reshape(-1)[intern]
        np.testing.assert_allclose(got, ref, rtol=1e-4)

    def test_sweep_modes_agree(self, small_graph):
        n, src, dst = small_graph
        g = GraphPAL.from_edges(src, dst, n_partitions=8, max_id=n - 1)
        dg = build_device_graph(g)
        x = jnp.asarray(
            np.random.default_rng(0).normal(size=(dg.n_partitions, dg.interval_len, 16))
        ).astype(jnp.float32)
        a = edge_centric_sweep(dg, x, lambda s: s, mode="dense_gather")
        b = edge_centric_sweep(dg, x, lambda s: s, mode="psw_windows")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)

    def test_sweep_jits(self, small_graph):
        n, src, dst = small_graph
        g = GraphPAL.from_edges(src, dst, n_partitions=4, max_id=n - 1)
        dg = build_device_graph(g)
        f = jax.jit(lambda x: edge_centric_sweep(dg, x, lambda s: s * 2.0,
                                                 mode="psw_windows"))
        x = jnp.ones((dg.n_partitions, dg.interval_len, 4), jnp.float32)
        out = f(x)
        assert out.shape == (dg.n_partitions, dg.interval_len, 4)
        assert bool(jnp.isfinite(out).all())


class TestQueries:
    def test_fof_matches_reference(self, small_graph):
        n, src, dst = small_graph
        g = GraphPAL.from_edges(src, dst, n_partitions=4, max_id=n - 1)
        for v in [0, 7, 100]:
            got = friends_of_friends(g, v)
            friends = np.unique(dst[src == v])
            ref = np.unique(np.concatenate([dst[src == f] for f in friends])
                            ) if friends.size else np.empty(0, np.int64)
            ref = np.setdiff1d(ref, np.concatenate([friends, [v]]))
            assert np.array_equal(np.sort(got), np.sort(ref)), v

    def test_fof_on_lsm(self, small_graph):
        n, src, dst = small_graph
        iv = IntervalMap.for_capacity(n - 1, 8)
        t = LSMTree(iv, n_levels=2, branching=4, buffer_cap=500,
                    max_partition_edges=800)
        t.insert_edges(src, dst)
        v = 7
        got = friends_of_friends(t, v)
        friends = np.unique(dst[src == v])
        ref = np.unique(np.concatenate([dst[src == f] for f in friends]))
        ref = np.setdiff1d(ref, np.concatenate([friends, [v]]))
        assert np.array_equal(np.sort(got), np.sort(ref))

    def test_bfs_depths(self):
        # path graph 0->1->2->3 plus shortcut 0->2
        g = GraphPAL.from_edges([0, 1, 2, 0], [1, 2, 3, 2], n_partitions=2, max_id=3)
        d = bfs(g, 0, max_depth=5)
        assert d == {0: 0, 1: 1, 2: 1, 3: 2}

    def test_bottom_up_equals_top_down(self, small_graph):
        n, src, dst = small_graph
        g = GraphPAL.from_edges(src, dst, n_partitions=4, max_id=n - 1)
        f = Frontier(list(range(0, n, 2)))  # large frontier
        td = traverse_out(g, f, bottom_up_threshold=1.1)   # force top-down
        bu = traverse_out(g, f, bottom_up_threshold=0.0)   # force bottom-up
        assert np.array_equal(td.ids, bu.ids)

    def test_shortest_path(self):
        g = GraphPAL.from_edges([0, 1, 2, 3, 0], [1, 2, 3, 4, 9], n_partitions=2,
                                max_id=9)
        assert shortest_path(g, 0, 4, max_depth=5) == 4
        assert shortest_path(g, 0, 9, max_depth=5) == 1
        assert shortest_path(g, 4, 0, max_depth=5) is None
        assert shortest_path(g, 0, 4, max_depth=5, two_sided=False) == 4
