"""PSW ring-op tests. The 1-device ring runs in-process; the 8-device ring
(real collective-permute semantics) runs in a subprocess because the device
count must be set before jax initializes."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.graph.psw_ops import (local_edge_softmax, local_gather,
                                 local_scatter_sum, ring_gather)
from repro.graph.segment_ops import edge_softmax


@pytest.fixture(scope="module")
def mesh1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


class TestRingSingleDevice:
    def test_ring_gather_matches_take(self, mesh1):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(64, 5)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, 64, (40,)), jnp.int32)
        np.testing.assert_allclose(np.asarray(ring_gather(x, idx, mesh1)),
                                   np.asarray(x[idx]))

    def test_ring_gather_vjp(self, mesh1):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(32, 3)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, 32, (20,)), jnp.int32)
        g = jax.grad(lambda x: (ring_gather(x, idx, mesh1) ** 2).sum())(x)
        gref = jax.grad(lambda x: (x[idx] ** 2).sum())(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gref), rtol=1e-6)

    def test_local_ops(self, mesh1):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(64, 5)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, 64, (40,)), jnp.int32)
        v = jnp.asarray(rng.normal(size=(40, 5)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(local_gather(x, idx, mesh1)), np.asarray(x[idx]))
        np.testing.assert_allclose(
            np.asarray(local_scatter_sum(v, idx, 64, mesh1)),
            np.asarray(jax.ops.segment_sum(v, idx, num_segments=64)),
            rtol=1e-6)
        s = jnp.asarray(rng.normal(size=(40,)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(local_edge_softmax(s, idx, 64, mesh1)),
            np.asarray(edge_softmax(s, idx, 64)), rtol=1e-5)


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.graph.psw_ops import (ring_gather, ring_scatter_sum,
                                     local_gather, local_scatter_sum)
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ('data', 'model'))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 5)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 64, (40,)), jnp.int32)
    np.testing.assert_allclose(np.asarray(ring_gather(x, idx, mesh)),
                               np.asarray(x[idx]), rtol=1e-6)
    g = jax.grad(lambda x: (ring_gather(x, idx, mesh) ** 2).sum())(x)
    gref = jax.grad(lambda x: (x[idx] ** 2).sum())(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gref), rtol=1e-5)
    v = jnp.asarray(rng.normal(size=(40, 5)).astype(np.float32))
    out = ring_scatter_sum(v, idx, 64, mesh)
    ref = jax.ops.segment_sum(v, idx, num_segments=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
    gv = jax.grad(lambda v: (ring_scatter_sum(v, idx, 64, mesh) ** 2).sum())(v)
    gvref = jax.grad(lambda v: (jax.ops.segment_sum(
        v, idx, num_segments=64) ** 2).sum())(v)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(gvref), rtol=1e-5)
    # local ops with shard-aligned indices
    n_loc = 8
    idx_l = jnp.concatenate([
        jnp.asarray(rng.integers(i * n_loc, (i + 1) * n_loc, (5,)))
        for i in range(8)]).astype(jnp.int32)
    np.testing.assert_allclose(np.asarray(local_gather(x, idx_l, mesh)),
                               np.asarray(x[idx_l]), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(local_scatter_sum(v, idx_l, 64, mesh)),
        np.asarray(jax.ops.segment_sum(v, idx_l, num_segments=64)), rtol=1e-5)
    print('MULTI_OK')
""")


def test_ring_ops_8_devices():
    proc = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env={"PYTHONPATH": "src",
                               "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MULTI_OK" in proc.stdout
