"""Service-tier tests (ISSUE 4): snapshot isolation vs a live writer,
background maintenance, crash recovery of every mutation type, WAL
segment rotation/compaction, backpressure."""
import os
import shutil
import threading

import numpy as np
import pytest

from repro.core import (
    GraphDB,
    IntervalMap,
    LSMTree,
    ServiceDB,
    Snapshot,
)
from repro.core.query import bfs, friends_of_friends


def make_service(tmp_path, name="db", **kw):
    opts = dict(max_id=9999, n_partitions=16, n_levels=3, branching=4,
                buffer_cap=2000, max_partition_edges=8000,
                persist_min_edges=512, wal_segment_bytes=64 << 10,
                checkpoint_interval_ops=10 ** 9)
    opts.update(kw)
    return ServiceDB.create(str(tmp_path / name), **opts)


def ref_tree(column_dtypes=None):
    iv = IntervalMap.for_capacity(9999, 16)
    return LSMTree(iv, n_levels=3, branching=4, buffer_cap=2000,
                   max_partition_edges=8000, column_dtypes=column_dtypes or {})


def coo_sorted(g):
    return sorted(zip(*map(list, g.to_coo())))


def apply_ops(tree, ops):
    """Serial replay of a recorded op list into a plain RAM tree."""
    for op in ops:
        if op[0] == "insert":
            tree.insert_edges(op[1], op[2], columns=op[3])
        elif op[0] == "delete":
            tree.delete_edge(op[1], op[2])
        else:
            tree.update_edge_column(op[1], op[2], op[3], op[4])


class TestSnapshotIsolation:
    def test_snapshot_pinned_through_delete_compaction_gc(self, tmp_path):
        """The acceptance scenario: a snapshot opened BEFORE a
        delete + compaction + checkpoint-GC + WAL-rotation cycle still
        answers every query identically to a serial replay of its pinned
        prefix, while the store's on-disk WAL bytes shrink."""
        svc = make_service(tmp_path, column_dtypes={"w": np.float32},
                           wal_segment_bytes=8 << 10)
        rng = np.random.default_rng(0)
        src = rng.integers(0, 10000, 30000)
        dst = rng.integers(0, 10000, 30000)
        w = rng.random(30000).astype(np.float32)
        svc.insert_edges(src, dst, columns={"w": w})
        wal_peak = svc.tree.wal.on_disk_bytes()
        snap = svc.begin_snapshot()

        # writer churns: more inserts, deletes, column writes, checkpoints
        s2 = rng.integers(0, 10000, 20000)
        d2 = rng.integers(0, 10000, 20000)
        svc.insert_edges(s2, d2, columns={"w": np.ones(20000, np.float32)})
        for i in range(100):
            svc.delete_edge(int(src[i]), int(dst[i]))
        svc.update_edge_column(int(src[200]), int(dst[200]), "w", -1.0)
        svc.checkpoint()  # persists, GCs store files, compacts WAL segments
        svc.checkpoint()
        assert svc.tree.wal.on_disk_bytes() < wal_peak, \
            "WAL compaction never reclaimed bytes"

        # serial replay reference: exactly the ops before the pin
        ref = ref_tree({"w": np.float32})
        ref.insert_edges(src, dst, columns={"w": w})
        assert coo_sorted(snap) == coo_sorted(ref)
        eng, reng = snap.storage_engine(), ref.storage_engine()
        vs = [int(v) for v in np.unique(src)[:40]]
        a = eng.edge_columns_batch(vs, names=["w"])
        b = reng.edge_columns_batch(vs, names=["w"])
        for i in range(len(vs)):
            sa, sb = a.slice_of(i), b.slice_of(i)
            assert sorted(zip(a.dst[sa].tolist(),
                              a.columns["w"][sa].tolist())) == \
                sorted(zip(b.dst[sb].tolist(), b.columns["w"][sb].tolist()))
        for v in vs[:10]:
            assert np.array_equal(np.sort(snap.out_neighbors(v)),
                                  np.sort(ref.out_neighbors(v)))
        snap.release()
        assert not os.path.exists(snap.dir)
        svc.close()

    def test_snapshot_sees_unflushed_buffers_deletes_and_columns(self, tmp_path):
        """The pin covers state that exists ONLY in buffers/WAL (nothing
        checkpointed yet): inserts with columns, a delete, a column
        write."""
        svc = make_service(tmp_path, column_dtypes={"w": np.float32},
                           maintenance=False, buffer_cap=10 ** 9)
        svc.insert_edges([1, 2, 3], [4, 5, 6],
                         columns={"w": np.asarray([1., 2., 3.], np.float32)})
        svc.delete_edge(2, 5)
        svc.update_edge_column(3, 6, "w", 7.5)
        snap = svc.begin_snapshot()
        assert coo_sorted(snap) == sorted([(1, 4), (3, 6)])
        batch = snap.storage_engine().edge_columns_batch([3], names=["w"])
        assert batch.columns["w"].tolist() == [7.5]
        svc.close()

    def test_snapshot_reopen_across_sessions(self, tmp_path):
        svc = make_service(tmp_path)
        rng = np.random.default_rng(1)
        svc.insert_edges(rng.integers(0, 10000, 5000),
                         rng.integers(0, 10000, 5000))
        snap = svc.begin_snapshot()
        ref = coo_sorted(snap)
        path = snap.dir
        snap.close()
        # a second opener (another thread/process would do the same)
        again = Snapshot.open(path)
        assert coo_sorted(again) == ref
        svc.close()

    def test_snapshot_ids_survive_service_reopen(self, tmp_path):
        """Regression: the session counter restarts per instance, so a
        reopened ServiceDB used to collide with a still-live session dir
        from the previous instance (FileExistsError)."""
        svc = make_service(tmp_path)
        svc.insert_edges([1, 2], [3, 4])
        snap = svc.begin_snapshot()  # NOT released: the dir stays
        svc.close()
        svc2 = ServiceDB.open(str(tmp_path / "db"))
        snap2 = svc2.begin_snapshot()
        assert snap2.dir != snap.dir
        assert coo_sorted(snap2) == coo_sorted(snap)
        svc2.close()

    def test_snapshot_requires_durability(self, tmp_path):
        db = GraphDB.create(str(tmp_path / "nd"), max_id=999, durable=False)
        with pytest.raises(ValueError):
            ServiceDB(db)


class TestConcurrentStress:
    def test_writers_vs_snapshot_readers(self, tmp_path):
        """Writer thread interleaves inserts and deletes while the main
        thread pins snapshots at arbitrary moments and runs FoF/BFS on
        them. Every snapshot must equal the serial replay of exactly the
        ops applied before its pin (the op log and the WAL are appended
        under the same lock, so the log prefix at pin time IS the pinned
        prefix; backpressure is disabled because its condition-wait
        releases the outer lock mid-append, which would unlink them)."""
        svc = make_service(tmp_path, buffer_cap=1000,
                           backpressure_edges=10 ** 9)
        rng = np.random.default_rng(2)
        n_rounds = 60
        batches = [
            (rng.integers(0, 10000, 200), rng.integers(0, 10000, 200))
            for _ in range(n_rounds)
        ]
        log = []
        stop = threading.Event()

        def writer():
            for bi, (s, d) in enumerate(batches):
                with svc._lock:
                    svc.insert_edges(s, d)
                    log.append(("insert", s, d, None))
                if bi % 3 == 2:  # delete something known to exist
                    s0, d0 = int(s[0]), int(d[0])
                    # lock ORDER: the delete's merge slot before the
                    # service lock (matching ServiceDB.delete_edge, which
                    # re-acquires both reentrantly)
                    with svc._merge_slot_of(d0), svc._lock:
                        svc.delete_edge(s0, d0)
                        log.append(("delete", s0, d0))
            stop.set()

        t = threading.Thread(target=writer)
        t.start()
        checked = 0
        try:
            # keep pinning until the writer is done AND we verified at
            # least a few mid-stream snapshots (post-stop pins are still
            # meaningful: they cover the full log)
            while not stop.is_set() or checked < 4:
                with svc._lock:
                    snap = svc.begin_snapshot()
                    prefix = list(log)
                ref = ref_tree()
                apply_ops(ref, prefix)
                assert coo_sorted(snap) == coo_sorted(ref)
                if prefix:
                    v = int(prefix[0][1][0])
                    assert np.array_equal(
                        np.sort(friends_of_friends(snap.storage_engine(), v)),
                        np.sort(friends_of_friends(ref.storage_engine(), v)))
                    assert bfs(snap.storage_engine(), v, max_depth=2) == \
                        bfs(ref.storage_engine(), v, max_depth=2)
                snap.release()
                checked += 1
        finally:
            t.join()
            svc.close()
        assert checked >= 4
        assert svc.stats.flushes > 0, "maintenance thread never drained"

    def test_maintenance_death_surfaces_to_writers(self, tmp_path):
        """If the maintenance thread dies (e.g. disk full mid-persist),
        writers must get the error instead of hanging forever in the
        backpressure wait."""
        svc = make_service(tmp_path, buffer_cap=100, backpressure_edges=300)

        def boom(j):
            raise OSError("simulated ENOSPC")

        # drain_buffer is the first step of BOTH the serial flush and the
        # pipelined flush job — patching it kills either maintenance mode
        svc.tree.drain_buffer = boom
        rng = np.random.default_rng(9)
        with pytest.raises((RuntimeError, OSError)):
            for _ in range(50):  # cross the cap, then observe the death
                svc.insert_edges(rng.integers(0, 10000, 100),
                                 rng.integers(0, 10000, 100))
        assert svc.maintenance_error is not None
        del svc.tree.drain_buffer
        svc.maintenance_error = None  # cleared: allow the closing checkpoint
        svc.close()

    def test_backpressure_bounds_dirty_set(self, tmp_path):
        svc = make_service(tmp_path, buffer_cap=500, backpressure_edges=2000)
        rng = np.random.default_rng(3)
        peak = 0
        for _ in range(40):
            svc.insert_edges(rng.integers(0, 10000, 400),
                             rng.integers(0, 10000, 400))
            peak = max(peak, svc.tree.total_buffered())
        # one in-flight batch may overshoot the bound before the wait
        assert peak <= 2000 + 400
        assert svc.stats.flushes > 0
        n = svc.n_edges
        svc.close()
        assert GraphDB.open(svc.db.dir).n_edges == n == 16000


class TestCrashRecovery:
    def test_crash_during_background_compaction(self, tmp_path):
        """Freeze the store mid-maintenance (lock held = the only instant a
        copy is consistent the way a kill is) with a half-written manifest
        lying around; recovery must reproduce the exact live state."""
        svc = make_service(tmp_path, buffer_cap=800,
                           checkpoint_interval_ops=3000)
        rng = np.random.default_rng(4)
        for _ in range(15):  # keep maintenance busy: flushes + checkpoints
            svc.insert_edges(rng.integers(0, 10000, 1000),
                             rng.integers(0, 10000, 1000))
        for i in range(20):
            svc.delete_edge(int(rng.integers(0, 10000)),
                            int(rng.integers(0, 10000)))
        with svc._lock:  # simulated kill: snapshot the dir at a WAL boundary
            svc.tree.wal_flush(fsync=False)
            live = coo_sorted(svc.tree)
            with open(str(tmp_path / "db" / (GraphDB.MANIFEST + ".tmp")),
                      "w") as f:
                f.write('{"config": TRUNCATED')  # torn manifest next to real
            crash = str(tmp_path / "crash")
            shutil.copytree(str(tmp_path / "db"), crash)
        svc.close()
        db2 = GraphDB.open(crash)
        assert coo_sorted(db2) == live
        assert svc.stats.flushes > 0 or svc.stats.checkpoints > 0

    def test_buffered_columns_survive_crash(self, tmp_path):
        """Regression (ROADMAP "Columns in the WAL"): attribute columns
        buffered since the last checkpoint — plus deletes and in-place
        column writes — must replay from the WAL. The old WAL dropped all
        of them (it only recorded src/dst/etype)."""
        svc = make_service(tmp_path, column_dtypes={"w": np.float32},
                           maintenance=False, buffer_cap=10 ** 9)
        rng = np.random.default_rng(5)
        src = rng.integers(0, 10000, 3000)
        dst = rng.integers(0, 10000, 3000)
        w1 = rng.random(3000).astype(np.float32)
        svc.insert_edges(src, dst, columns={"w": w1})
        svc.checkpoint()
        # post-checkpoint, pre-flush: lives only in buffers + WAL
        s2 = rng.integers(0, 10000, 2000)
        d2 = rng.integers(0, 10000, 2000)
        w2 = (rng.random(2000) + 5).astype(np.float32)
        svc.insert_edges(s2, d2, columns={"w": w2})
        svc.delete_edge(int(src[0]), int(dst[0]))
        svc.update_edge_column(int(src[1]), int(dst[1]), "w", 99.5)
        svc.tree.wal_flush(fsync=False)
        crash = str(tmp_path / "crash")
        shutil.copytree(str(tmp_path / "db"), crash)  # kill before any flush

        db2 = GraphDB.open(crash)
        ref = ref_tree({"w": np.float32})
        ref.insert_edges(src, dst, columns={"w": w1})
        ref.insert_edges(s2, d2, columns={"w": w2})
        ref.delete_edge(int(src[0]), int(dst[0]))
        ref.update_edge_column(int(src[1]), int(dst[1]), "w", 99.5)
        assert coo_sorted(db2) == coo_sorted(ref)
        eng, reng = db2.storage_engine(), ref.storage_engine()
        vs = [int(v) for v in np.unique(np.concatenate([src[:30], s2[:30]]))]
        a = eng.edge_columns_batch(vs, names=["w"])
        b = reng.edge_columns_batch(vs, names=["w"])
        for i in range(len(vs)):
            sa, sb = a.slice_of(i), b.slice_of(i)
            assert sorted(zip(a.dst[sa].tolist(),
                              a.columns["w"][sa].tolist())) == \
                sorted(zip(b.dst[sb].tolist(), b.columns["w"][sb].tolist()))
        svc.close()


class TestCheckpointManager:
    def test_save_lsm_captures_live_buffers(self, tmp_path):
        """checkpoint/manager satellite: save_lsm on a store with unflushed
        buffers restores them, columns included (the old checkpoints
        silently dropped everything after the last flush)."""
        from repro.checkpoint.manager import restore_lsm, save_lsm
        svc = make_service(tmp_path, column_dtypes={"w": np.float32},
                           maintenance=False, buffer_cap=10 ** 9)
        rng = np.random.default_rng(6)
        src = rng.integers(0, 10000, 20000)
        dst = rng.integers(0, 10000, 20000)
        w = rng.random(20000).astype(np.float32)
        svc.insert_edges(src[:15000], dst[:15000], columns={"w": w[:15000]})
        svc.checkpoint()
        svc.insert_edges(src[15000:], dst[15000:], columns={"w": w[15000:]})
        assert svc.tree.total_buffered() > 0
        ck = str(tmp_path / "ckpt")
        m = save_lsm(svc.db, ck)
        assert m.get("buffers") == "buffers.npz"
        t2 = restore_lsm(ck)
        assert coo_sorted(t2) == coo_sorted(svc.tree)
        # buffered columns came back, not zeros
        eng, reng = t2.storage_engine(), svc.db.storage_engine()
        vs = [int(v) for v in np.unique(src[15000:])[:20]]
        a = eng.edge_columns_batch(vs, names=["w"])
        b = reng.edge_columns_batch(vs, names=["w"])
        for i in range(len(vs)):
            sa, sb = a.slice_of(i), b.slice_of(i)
            assert sorted(zip(a.dst[sa].tolist(),
                              a.columns["w"][sa].tolist())) == \
                sorted(zip(b.dst[sb].tolist(), b.columns["w"][sb].tolist()))
        svc.close()
