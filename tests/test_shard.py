"""ISSUE 8: shared-nothing interval sharding.

Covers the tentpole and its satellites:

  * ownership math — `shard_of` is exactly interval ownership,
  * the wire protocol — roundtrip, checksum detection, typed remote errors,
  * bitwise equality — every sharded read (out/in neighbors, degrees,
    k-hop, FoF) equals the unsharded engine on the same op prefix,
  * epoch semantics — a ShardedView is frozen under concurrent writes and
    raises `ShardEpochLost` (never splices epochs) across a restart,
  * failure/restart — crashed workers respawn on their durable dirs; reads
    retry once, writes never,
  * cross-process reads — a subprocess opens the shards' pinned session
    dirs and returns bitwise-identical out_neighbors/FoF to the live
    in-process epoch view, while a writer keeps mutating,
  * view-addressed snapshots — `begin_snapshot(view=...)` pins a PAST
    epoch's exact logical state (the ManifestView-across-the-boundary
    satellite).
"""
import os
import shutil
import socket
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core import (
    CRASH_EXIT_CODE,
    ServiceDB,
    ShardEpochLost,
    ShardProtocolError,
    ShardRemoteError,
    ShardRouter,
    Snapshot,
    fp_clear,
    fp_set,
    khop,
    shard_of,
    telemetry,
    two_hop_counts,
)
from repro.core import shardrouter as sr
from repro.core.engine import StorageEngine
from repro.core.failpoints import ENV_VAR
from repro.core.query import consistent_engine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

N_ID = 20_000
DB_KW = dict(n_partitions=8, n_levels=2, branching=4, buffer_cap=4000,
             max_partition_edges=50_000, persist_min_edges=512)


def _edges(seed=7, n=30_000):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, N_ID, n, dtype=np.int64),
            rng.integers(0, N_ID, n, dtype=np.int64))


# ---------------------------------------------------------------------------
# ownership + protocol units (no processes)
# ---------------------------------------------------------------------------
def test_shard_of_is_interval_ownership():
    from repro.core import IntervalMap
    iv = IntervalMap.for_capacity(N_ID, 8)
    vs = np.arange(0, N_ID, 37, dtype=np.int64)
    for n_shards in (1, 2, 4, 8):
        expect = np.asarray(iv.interval_of(iv.to_internal(vs))) % n_shards
        got = shard_of(vs, iv.n_partitions, n_shards)
        assert np.array_equal(got, expect)


def test_frame_roundtrip_and_checksum():
    a, b = socket.socketpair()
    try:
        meta = {"op": "expand", "kw": {"direction": "out"}}
        arrays = {"vs": np.arange(17, dtype=np.int64),
                  "f": np.linspace(0, 1, 5, dtype=np.float32)}
        sr.send_frame(a, sr.ST_REQUEST, meta, arrays)
        status, m2, a2 = sr.recv_frame(b)
        assert status == sr.ST_REQUEST
        assert m2["op"] == "expand" and m2["kw"] == {"direction": "out"}
        assert np.array_equal(a2["vs"], arrays["vs"])
        assert np.array_equal(a2["f"], arrays["f"])
        assert a2["f"].dtype == np.float32

        # flip one payload byte in flight: the wsum32 must catch it
        payload = sr.encode_payload(meta, arrays)
        head = sr._HEADER.pack(sr._MAGIC, len(payload),
                               sr.checksum32(payload), sr.ST_REQUEST)
        corrupt = bytearray(payload)
        corrupt[len(corrupt) // 2] ^= 0x40
        a.sendall(head + bytes(corrupt))
        with pytest.raises(ShardProtocolError):
            sr.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_bad_magic_rejected():
    a, b = socket.socketpair()
    try:
        a.sendall(sr._HEADER.pack(0xDEAD, 4, 0, sr.ST_REQUEST) + b"ABCD")
        with pytest.raises(ShardProtocolError):
            sr.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_send_failpoint_site_fires():
    a, b = socket.socketpair()
    fp_set("shard.rpc.send", "raise")
    try:
        with pytest.raises(Exception):
            sr.send_frame(a, sr.ST_OK, {"op": "ping"})
    finally:
        fp_clear("shard.rpc.send")
        a.close()
        b.close()


def test_recv_failpoint_site_fires():
    a, b = socket.socketpair()
    try:
        sr.send_frame(a, sr.ST_OK, {"op": "ping"})
        fp_set("shard.rpc.recv", "raise")
        try:
            with pytest.raises(Exception):
                sr.recv_frame(b)
        finally:
            fp_clear("shard.rpc.recv")
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# the sharded store vs the unsharded reference
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def stores(tmp_path_factory):
    """One 2-shard router + one unsharded ServiceDB fed the same op
    prefix (module-scoped: worker spawn is seconds on a small box)."""
    base = tmp_path_factory.mktemp("shard")
    src, dst = _edges()
    ref = ServiceDB.create(str(base / "ref"), max_id=N_ID, **DB_KW)
    ref.insert_edges(src, dst)
    router = ShardRouter.create(str(base / "sharded"), max_id=N_ID,
                                n_shards=2, **DB_KW)
    router.insert_edges(src, dst)
    yield router, ref, src, dst
    router.close()
    ref.close()


def test_edge_counts_match(stores):
    router, ref, src, _ = stores
    assert router.n_edges == ref.n_edges == src.shape[0]


def test_single_vertex_reads_bitwise(stores):
    router, ref, src, dst = stores
    for v in [int(src[0]), int(dst[1]), int(src[2]), 0, N_ID - 1]:
        assert np.array_equal(np.sort(router.out_neighbors(v)),
                              np.sort(ref.out_neighbors(v)))
        assert np.array_equal(router.in_neighbors(v),
                              np.sort(ref.in_neighbors(v)))


def test_khop_and_fof_bitwise(stores):
    router, ref, src, _ = stores
    seeds = np.unique(src[:64])
    with consistent_engine(router) as eng, ref.read_view() as view:
        reng = view.storage_engine()
        for direction in ("out", "in"):
            ours = khop(eng, seeds, 2, direction=direction)
            theirs = khop(reng, seeds, 2, direction=direction)
            assert len(ours.levels) == len(theirs.levels)
            for a, b in zip(ours.levels, theirs.levels):
                assert np.array_equal(a, b)
            assert np.array_equal(ours.visited, theirs.visited)
        f1 = two_hop_counts(eng, seeds[:16])
        f2 = two_hop_counts(reng, seeds[:16])
        assert np.array_equal(f1.ids, f2.ids)
        assert np.array_equal(f1.counts, f2.counts)
        assert np.array_equal(f1.offsets, f2.offsets)


def test_degree_batch_bitwise(stores):
    router, ref, src, dst = stores
    vs = np.unique(np.concatenate([src[:200], dst[:200]]))
    with consistent_engine(router) as eng, ref.read_view() as view:
        reng = view.storage_engine()
        assert np.array_equal(eng.out_degree_batch(vs),
                              reng.out_degree_batch(vs))
        assert np.array_equal(eng.in_degree_batch(vs),
                              reng.in_degree_batch(vs))


def test_hop_mode_clamps_to_sparse(stores):
    """Requesting stream/kernel on the sharded engine must clamp to the
    sparse scatter/gather path, not ship the edge set over IPC — and the
    answer stays bitwise-equal."""
    router, ref, src, _ = stores
    seeds = np.unique(src[:32])
    with consistent_engine(router) as eng, ref.read_view() as view:
        assert eng.supported_hop_modes == ("sparse",)
        ours = khop(eng, seeds, 2, dense="stream")  # would need edge_chunks
        theirs = khop(view.storage_engine(), seeds, 2)
        for a, b in zip(ours.levels, theirs.levels):
            assert np.array_equal(a, b)


def test_remote_typed_error(stores):
    router, _, _, _ = stores
    with pytest.raises(ShardRemoteError):
        router._call(0, "no_such_op", {})


def test_sharded_view_frozen_under_writes(stores):
    # NOTE: mutates the shared router (only) — every test comparing the
    # router against `ref` on the same op prefix is defined ABOVE this one
    router, _, src, dst = stores
    v = int(src[0])
    with router.pin_view() as view:
        before = np.sort(view.out_neighbors(v))
        n_before = view.n_edges
        router.insert_edges([v] * 8, np.arange(8, dtype=np.int64) + 1)
        assert np.array_equal(np.sort(view.out_neighbors(v)), before)
        assert view.n_edges == n_before
    live = router.out_neighbors(v)
    assert live.shape[0] == before.shape[0] + 8


def test_io_stats_partitioned(stores):
    """After a checkpoint, a broad frontier read touches disk blocks on
    EVERY shard — the per-shard accounting bench_shard gates on."""
    router, _, src, _ = stores
    router.checkpoint_all()
    base = [s["block_reads"] for s in router.io_stats()]
    seeds = np.unique(src[:512])
    with consistent_engine(router) as eng:
        eng.expand_frontier(seeds, "out")
    after = [s["block_reads"] for s in router.io_stats()]
    assert all(b >= a for a, b in zip(base, after))
    assert sum(after) > sum(base)
    grew = sum(1 for a, b in zip(base, after) if b > a)
    assert grew == len(router.shards)


# ---------------------------------------------------------------------------
# failure / restart semantics
# ---------------------------------------------------------------------------
class TestRestart:
    def _mk(self, tmp_path, n_shards=1):
        return ShardRouter.create(str(tmp_path / "rt"), max_id=N_ID,
                                  n_shards=n_shards, **DB_KW)

    def test_read_retries_after_worker_death(self, tmp_path):
        router = self._mk(tmp_path)
        try:
            src, dst = _edges(seed=3, n=2000)
            router.insert_edges(src, dst)
            expect = np.sort(router.out_neighbors(int(src[0])))
            router.shards[0].proc.kill()
            router.shards[0].proc.join()
            got = np.sort(router.out_neighbors(int(src[0])))
            assert np.array_equal(got, expect)
            assert router.restarts == 1
            assert router.health()[0]["alive"]
        finally:
            router.close()

    def test_write_never_retries(self, tmp_path):
        router = self._mk(tmp_path)
        try:
            router.shards[0].proc.kill()
            router.shards[0].proc.join()
            with pytest.raises(sr.ShardUnavailable):
                router.insert_edges([1], [2])
            # the durable state is intact; the NEXT write (after the
            # caller-visible failure) lands on a recovered worker
            router.restart_shard(0)
            router.insert_edges([1], [2])
            assert np.array_equal(router.out_neighbors(1), [2])
        finally:
            router.close()

    def test_epoch_pin_dies_with_worker(self, tmp_path):
        router = self._mk(tmp_path)
        try:
            router.insert_edges([5], [6])
            view = router.pin_view()
            assert np.array_equal(view.out_neighbors(5), [6])
            router.shards[0].proc.kill()
            router.shards[0].proc.join()
            with pytest.raises(ShardEpochLost):
                view.out_neighbors(5)
            view.release()
            # a FRESH view on the recovered worker serves again
            with router.pin_view() as v2:
                assert np.array_equal(v2.out_neighbors(5), [6])
        finally:
            router.close()

    def test_worker_op_crash_failpoint(self, tmp_path, monkeypatch):
        """Arm `shard.worker.op=crash@1` through the environment channel:
        the spawned worker survives its readiness ping (hit 1), dies
        mid-first-real-op with os._exit(41), and the router's read path
        respawns it (env cleared — the respawn is clean) and retries."""
        monkeypatch.setenv(ENV_VAR, "shard.worker.op=crash@1")
        router = self._mk(tmp_path)
        monkeypatch.delenv(ENV_VAR)
        try:
            with pytest.raises(sr.ShardUnavailable):
                router.insert_edges([1], [2])  # writes must NOT retry
            router.shards[0].proc.join(timeout=30)
            assert router.shards[0].proc.exitcode == CRASH_EXIT_CODE
            got = router.out_neighbors(1)  # reads retry across the respawn
            assert router.restarts == 1
            assert got.shape[0] in (0, 1)  # WAL may or may not have acked
        finally:
            router.close()

    def test_worker_serve_crash_fails_spawn(self, tmp_path, monkeypatch):
        monkeypatch.setenv(ENV_VAR, "shard.worker.serve=crash")
        with pytest.raises(sr.ShardUnavailable):
            self._mk(tmp_path)


# ---------------------------------------------------------------------------
# cross-process reads of pinned shard views (satellite)
# ---------------------------------------------------------------------------
_SUBPROC = r"""
import json, sys
import numpy as np
from repro.core import Snapshot, two_hop_counts
from repro.core.engine import StorageEngine

spec = json.load(open(sys.argv[1]))
snaps = [Snapshot.open(d) for d in spec["dirs"]]

class Merged(StorageEngine):
    # all shards share ONE internal id space, so their slabs concatenate
    # into a single engine — the subprocess-side gather
    def _slabs(self):
        for s in snaps:
            yield from s.storage_engine()._slabs()

eng = Merged(snaps[0].tree)
out = {}
for v in spec["vertices"]:
    vals, _ = eng.out_neighbors_batch([v])
    out[f"out_{v}"] = np.sort(vals)
fof = two_hop_counts(eng, np.asarray(spec["seeds"], np.int64))
out["fof_ids"] = fof.ids
out["fof_counts"] = fof.counts
out["fof_offsets"] = fof.offsets
np.savez(spec["out"], **out)
"""


def test_subprocess_reads_pinned_view_bitwise(stores, tmp_path):
    """A subprocess opens every shard's exported session dir and must
    return bitwise-identical out_neighbors and FoF to the live in-process
    epoch view — while a concurrent writer keeps mutating the store."""
    import json
    router, _, src, _ = stores
    stop = threading.Event()
    dirs = []

    def writer():
        rng = np.random.default_rng(99)
        while not stop.is_set():
            router.insert_edges(rng.integers(0, N_ID, 64),
                                rng.integers(0, N_ID, 64))

    t = threading.Thread(target=writer)
    t.start()
    try:
        with router.pin_view() as view:
            dirs = view.begin_snapshot_dirs()
            vertices = [int(v) for v in np.unique(src[:8])]
            seeds = [int(v) for v in np.unique(src[8:24])]
            expect = {f"out_{v}": np.sort(view.out_neighbors(v))
                      for v in vertices}
            eng = view.storage_engine()
            fof = two_hop_counts(eng, np.asarray(seeds, np.int64))

            spec = {"dirs": dirs, "vertices": vertices, "seeds": seeds,
                    "out": str(tmp_path / "got.npz")}
            spec_path = str(tmp_path / "spec.json")
            with open(spec_path, "w") as f:
                json.dump(spec, f)
            env = dict(os.environ)
            env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
            proc = subprocess.run(
                [sys.executable, "-c", _SUBPROC, spec_path],
                capture_output=True, text=True, env=env, timeout=300)
            assert proc.returncode == 0, proc.stderr

            got = np.load(spec["out"])
            for v in vertices:
                assert np.array_equal(got[f"out_{v}"], expect[f"out_{v}"])
            assert np.array_equal(got["fof_ids"], fof.ids)
            assert np.array_equal(got["fof_counts"], fof.counts)
            assert np.array_equal(got["fof_offsets"], fof.offsets)

            # the writer really did race: the live state moved past the pin
            stop.set()
            t.join()
            assert router.n_edges > view.n_edges
    finally:
        stop.set()
        t.join()
        for d in dirs:
            shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------
# view-addressed snapshots (ManifestView across the boundary)
# ---------------------------------------------------------------------------
class TestViewAddressedSnapshot:
    def test_pins_past_epoch_exactly(self, tmp_path):
        svc = ServiceDB.create(str(tmp_path / "db"), max_id=N_ID, **DB_KW)
        try:
            src, dst = _edges(seed=11, n=5000)
            svc.insert_edges(src, dst)
            view = svc.read_view()
            svc.insert_edges(src + 1, dst)  # the view must NOT see these
            snap = svc.begin_snapshot(view=view)
            try:
                assert snap.n_edges == view.n_edges == src.shape[0]
                M = np.int64(N_ID + 1)
                vs, vd = view.to_coo()
                ss, sd = snap.to_coo()
                assert np.array_equal(
                    np.sort(np.asarray(vs) * M + np.asarray(vd)),
                    np.sort(np.asarray(ss) * M + np.asarray(sd)))
            finally:
                snap.release()
            view.release()
        finally:
            svc.close()

    def test_checkpointed_past_view_is_rejected_typed(self, tmp_path):
        svc = ServiceDB.create(str(tmp_path / "db"), max_id=N_ID, **DB_KW)
        try:
            svc.insert_edges(*_edges(seed=12, n=3000))
            view = svc.read_view()
            svc.insert_edges([1], [2])
            svc.checkpoint()  # manifest now covers past the view
            with pytest.raises(ValueError):
                svc.begin_snapshot(view=view)
            view.release()
        finally:
            svc.close()


# ---------------------------------------------------------------------------
# Snapshot path-relativity (satellite)
# ---------------------------------------------------------------------------
class TestSnapshotRelocatable:
    def _mk(self, tmp_path):
        svc = ServiceDB.create(str(tmp_path / "db"), max_id=N_ID, **DB_KW)
        src, dst = _edges(seed=21, n=4000)
        svc.insert_edges(src, dst)
        svc.checkpoint()  # disk partitions: the lazily-mmapped hazard
        return svc, src

    def test_moved_session_dir_opens(self, tmp_path):
        svc, src = self._mk(tmp_path)
        try:
            snap = svc.begin_snapshot()
            expect = {int(v): np.sort(snap.out_neighbors(int(v)))
                      for v in src[:5]}
            snap.close()
            moved = str(tmp_path / "elsewhere" / "session")
            os.makedirs(os.path.dirname(moved))
            shutil.move(snap.dir, moved)
            reopened = Snapshot.open(moved)
            for v, nb in expect.items():
                assert np.array_equal(np.sort(reopened.out_neighbors(v)), nb)
            reopened.release()
        finally:
            svc.close()

    def test_relative_path_survives_chdir(self, tmp_path):
        svc, src = self._mk(tmp_path)
        cwd = os.getcwd()
        try:
            snap = svc.begin_snapshot()
            v = int(src[0])
            expect = np.sort(snap.out_neighbors(v))
            snap.close()
            os.chdir(os.path.dirname(snap.dir))
            # open via a RELATIVE path, then chdir away BEFORE any read:
            # partition mmaps open lazily, so only abspath-at-open survives
            rel = Snapshot.open(os.path.basename(snap.dir))
            os.chdir(str(tmp_path))
            assert np.array_equal(np.sort(rel.out_neighbors(v)), expect)
            rel.close()
        finally:
            os.chdir(cwd)
            svc.close()


# ---------------------------------------------------------------------------
# cross-process trace stitching (ISSUE 9 satellite)
# ---------------------------------------------------------------------------
def test_sharded_fof_single_trace(stores):
    """One router-side query produces ONE trace: the root span's trace id
    appears on router-side RPC spans AND on worker-side op spans from at
    least two worker processes (the context rode in frame meta), and the
    merged export is a loadable Chrome-trace document."""
    import json
    router, _, src, _ = stores
    seeds = np.unique(src[:16])
    with telemetry.span("x.fof.query") as root:
        with consistent_engine(router) as eng:
            two_hop_counts(eng, seeds)
    doc = router.trace_export()
    json.dumps(doc)  # Perfetto/chrome://tracing-loadable envelope
    assert doc["traceEvents"]
    evs = [e for e in doc["traceEvents"]
           if e["args"].get("trace") == root.trace]
    worker_pids = {sp.proc.pid for sp in router.shards}
    pids = {e["pid"] for e in evs}
    assert os.getpid() in pids  # the router's own spans
    # the SAME trace reached >= 2 worker processes
    assert len(pids & worker_pids) >= 2
    assert any(e["name"] == "shard.rpc" and e["pid"] == os.getpid()
               for e in evs)
    assert any(e["name"] == "shard.op" and e["pid"] in worker_pids
               for e in evs)
    # spans are Chrome complete events on a shared epoch-us time axis
    for e in evs:
        assert e["ph"] == "X" and isinstance(e["ts"], int)


def test_trace_stitches_across_worker_restart(tmp_path):
    """A span held open across a worker kill + transparent read retry:
    the respawned worker (new pid) serves the retried op under the SAME
    trace id — the context re-ships with the retried frame."""
    router = ShardRouter.create(str(tmp_path / "rt"), max_id=N_ID,
                                n_shards=1, **DB_KW)
    try:
        src, dst = _edges(seed=31, n=2000)
        router.insert_edges(src, dst)
        old_pid = router.shards[0].proc.pid
        with telemetry.span("x.restart.query") as root:
            router.shards[0].proc.kill()
            router.shards[0].proc.join()
            router.out_neighbors(int(src[0]))  # retries across the respawn
        assert router.restarts == 1
        new_pid = router.shards[0].proc.pid
        assert new_pid != old_pid
        doc = router.trace_export()
        evs = [e for e in doc["traceEvents"]
               if e["args"].get("trace") == root.trace]
        assert any(e["pid"] == new_pid and e["name"] == "shard.op"
                   for e in evs)
    finally:
        router.close()


def test_router_metrics_snapshot_aggregates(stores):
    """metrics_snapshot() folds worker snapshots into one exact aggregate:
    worker-side WAL appends and RPC byte counts all visible router-side."""
    router, _, _, _ = stores
    doc = router.metrics_snapshot()
    assert len(doc["shards"]) == len(router.shards)
    agg = doc["aggregate"]
    assert set(agg["pids"]) >= {s["pid"] for s in doc["shards"]}
    # every worker appended to its own WAL during the fixture's inserts
    wal = sum(s["counters"].get("wal.appends", 0) for s in doc["shards"])
    assert wal > 0
    assert agg["counters"]["wal.appends"] >= wal
    # both sides of the frame protocol counted bytes
    assert doc["router"]["counters"]["shard.rpc.bytes_sent"] > 0
    assert agg["counters"]["shard.rpc.bytes_recv"] > 0
    reqs = doc["router"]["counters"]["shard.rpc.requests"]
    assert isinstance(reqs, dict) and sum(reqs.values()) > 0


def test_router_health_summary(stores):
    router, _, _, _ = stores
    h = router.health_summary()
    assert h["n_shards"] == h["alive"] == len(router.shards)
    assert h["ready"] is True
    assert h["poisoned_count"] == 0
    assert len(h["shards"]) == len(router.shards)
    for per in h["shards"]:
        assert per["alive"] and per["ready"]
