"""Substrate tests: optimizer, compression, data pipeline, checkpoints,
fault-tolerant resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_lsm, save_lsm
from repro.core import IntervalMap, LSMTree
from repro.data import (GraphStream, LinkBenchConfig, LinkBenchWorkload,
                        REQUEST_MIX, TokenStream, TokenStreamConfig)
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compressed_psum_tree, ef_compress, ef_decompress,
                         global_norm, linear_warmup_cosine)


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

        def loss(p):
            return jnp.sum((p["w"] - 1.0) ** 2)

        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(g, state, params, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)

    def test_clip_and_metrics(self):
        params = {"w": jnp.ones(4)}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=1e-3, clip_norm=0.5)
        g = {"w": jnp.full(4, 100.0)}
        _, _, metrics = adamw_update(g, state, params, cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_schedule_warmup(self):
        sched = linear_warmup_cosine(10, 100)
        assert float(sched(jnp.asarray(0))) == 0.0
        assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
        assert float(sched(jnp.asarray(100))) < 0.6


class TestCompression:
    def test_error_feedback_reduces_bias(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
        r = jnp.zeros_like(g)
        # repeated compression of the same gradient: error feedback makes the
        # RUNNING SUM converge to the true sum (bounded bias)
        total = jnp.zeros_like(g)
        for i in range(20):
            q, s, r = ef_compress(g, r)
            total = total + ef_decompress(q, s)
        np.testing.assert_allclose(np.asarray(total / 20), np.asarray(g),
                                   atol=float(jnp.abs(g).max()) / 127)

    def test_compressed_psum_shardmap(self):
        # 1-device mesh still exercises the shard_map plumbing
        from jax.sharding import Mesh
        from repro.jax_compat import shard_map
        from jax.sharding import PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
        g = {"w": jnp.arange(8.0)}
        r = {"w": jnp.zeros(8)}

        def f(g, r):
            return compressed_psum_tree(g, r, "dp")

        out, _ = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                           out_specs=(P(), P()))(g, r)
        np.testing.assert_allclose(np.asarray(out["w"]), np.arange(8.0),
                                   atol=7.0 / 127)


class TestData:
    def test_token_stream_deterministic_random_access(self):
        ts = TokenStream(TokenStreamConfig(vocab_size=100, batch=4, seq_len=16,
                                           seed=3))
        b7a = ts.batch_at(7)
        b7b = ts.batch_at(7)
        np.testing.assert_array_equal(b7a["tokens"], b7b["tokens"])
        assert b7a["tokens"].shape == (4, 16)
        assert b7a["tokens"].max() < 100
        # labels are next-token shifted
        assert not np.array_equal(ts.batch_at(8)["tokens"], b7a["tokens"])

    def test_graph_stream_power_law(self):
        gs = GraphStream(10_000, alpha=1.8, seed=0)
        src, dst = gs.next_edges(20_000)
        counts = np.bincount(dst, minlength=10_000)
        # heavy tail: top-1% of vertices should hold a large share
        top = np.sort(counts)[-100:].sum()
        assert top > 0.25 * counts.sum()

    def test_linkbench_mix(self):
        wl = LinkBenchWorkload(LinkBenchConfig(n_vertices=1000, seed=1))
        reqs = list(wl.requests(5000))
        frac = sum(r["op"] == "edge_outnbrs" for r in reqs) / len(reqs)
        assert abs(frac - REQUEST_MIX["edge_outnbrs"]) < 0.05
        src, dst, ts = wl.initial_graph()
        assert src.shape == dst.shape == ts.shape
        assert np.all(np.diff(ts) >= 0)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
        mgr.save(10, tree)
        out, step = mgr.restore(tree)
        assert step == 10
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(5.0))
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_keep_policy_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.zeros(2)}
        for s in [1, 2, 3]:
            mgr.save(s, jax.tree.map(lambda x: x + s, tree))
        assert mgr.latest_step() == 3
        files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
        assert len(files) == 2  # step 1 evicted
        out, _ = mgr.restore(tree, step=2)
        np.testing.assert_array_equal(np.asarray(out["x"]), [2.0, 2.0])

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(5, {"x": jnp.ones(3)}, blocking=False)
        mgr.wait()
        out, step = mgr.restore({"x": jnp.zeros(3)})
        assert step == 5

    def test_crash_mid_save_leaves_previous_intact(self, tmp_path):
        """A leftover .tmp file (simulated crash) must not break restore."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.ones(2)})
        # simulate crash: partial tmp file for step 2
        with open(os.path.join(tmp_path, "step_0000000002.npz.tmp"), "wb") as f:
            f.write(b"garbage")
        out, step = mgr.restore({"x": jnp.zeros(2)})
        assert step == 1

    def test_resume_training_bit_identical(self, tmp_path):
        """Train 10 steps straight vs train 5 + checkpoint + restore + 5:
        identical parameters — the fault-tolerance contract."""
        cfg = AdamWConfig(lr=0.05, weight_decay=0.01)
        ts = TokenStream(TokenStreamConfig(vocab_size=13, batch=2, seq_len=4))

        def make():
            p = {"w": jnp.ones((13, 13))}
            return p, adamw_init(p)

        def loss(p, batch):
            logits = p["w"][batch["tokens"].reshape(-1)]
            logz = jax.scipy.special.logsumexp(logits, -1)
            gold = jnp.take_along_axis(
                logits, batch["labels"].reshape(-1)[:, None], -1)[:, 0]
            return (logz - gold).mean()

        def step_fn(p, s, i):
            g = jax.grad(loss)(p, ts.batch_at(i))
            return adamw_update(g, s, p, cfg)[:2]

        p1, s1 = make()
        for i in range(10):
            p1, s1 = step_fn(p1, s1, i)

        p2, s2 = make()
        for i in range(5):
            p2, s2 = step_fn(p2, s2, i)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(5, {"params": p2, "opt": s2})
        restored, rstep = mgr.restore({"params": p2, "opt": s2})
        p3, s3 = restored["params"], restored["opt"]
        for i in range(rstep, 10):
            p3, s3 = step_fn(p3, s3, i)
        np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p3["w"]))


class TestLSMCheckpoint:
    def test_incremental_graph_checkpoint(self, tmp_path):
        iv = IntervalMap.for_capacity(9999, 8)
        t = LSMTree(iv, n_levels=2, branching=4, buffer_cap=200,
                    max_partition_edges=500)
        rng = np.random.default_rng(0)
        t.insert_edges(rng.integers(0, 10000, 1000), rng.integers(0, 10000, 1000))
        t.flush_all()
        d = str(tmp_path / "g")
        m1 = save_lsm(t, d)
        # second save with no changes: everything reused
        m2 = save_lsm(t, d)
        assert m2["written"] == 0 and m2["reused"] > 0
        # modify a little -> only touched partitions rewritten
        t.insert_edges(rng.integers(0, 10000, 300), rng.integers(0, 10000, 300))
        t.flush_all()
        m3 = save_lsm(t, d)
        assert 0 < m3["written"] <= m3["written"] + m3["reused"]

        t2 = restore_lsm(d)
        assert t2.n_edges == t.n_edges
        v = int(rng.integers(0, 10000))
        np.testing.assert_array_equal(np.sort(t.out_neighbors(v)),
                                      np.sort(t2.out_neighbors(v)))
