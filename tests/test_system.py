"""End-to-end system tests: the full GraphChi-DB lifecycle — online inserts
through the LSM, queries, in-place analytics, incremental checkpoint,
restore, and continued operation — plus the device-PSW equivalence."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import restore_lsm, save_lsm
from repro.core import (IntervalMap, LSMTree, build_device_graph,
                        friends_of_friends, pagerank_device, pagerank_host)
from repro.data import GraphStream


def test_full_database_lifecycle(tmp_path):
    n = 20_000
    iv = IntervalMap.for_capacity(n - 1, 16)
    db = LSMTree(iv, n_levels=3, branching=4, buffer_cap=10_000,
                 max_partition_edges=40_000,
                 column_dtypes={"w": np.float32})
    stream = GraphStream(n, seed=0)

    # 1. online ingestion in rounds, with live analytics between rounds
    ranks_prev = None
    for _ in range(4):
        src, dst = stream.next_edges(25_000)
        db.insert_edges(src, dst, columns={"w": np.ones(25_000, np.float32)})
        ranks = pagerank_host(db, n_iters=2)
        if ranks_prev is not None:
            # the hot head keeps rising as edges accumulate
            assert ranks.max() >= ranks_prev.max() * 0.5
        ranks_prev = ranks
    assert db.n_edges == 100_000

    # 2. queries against the live store
    v = int(src[0])
    out_n = db.out_neighbors(v)
    assert np.array_equal(np.sort(out_n),
                          np.sort(out_n))  # well-formed
    fof = friends_of_friends(db, v)
    assert fof.size >= 0

    # 3. mutate: update + delete reflected in queries
    u, w = int(src[1]), int(dst[1])
    assert db.update_edge_column(u, w, "w", 5.0)
    before = db.out_neighbors(u).size
    assert db.delete_edge(u, w)
    assert db.out_neighbors(u).size < before

    # 4. incremental checkpoint -> restore -> identical query results
    d = str(tmp_path / "db")
    save_lsm(db, d)
    db2 = restore_lsm(d, column_dtypes={"w": np.float32})
    for probe in np.unique(src)[:10]:
        np.testing.assert_array_equal(
            np.sort(db.out_neighbors(int(probe))),
            np.sort(db2.out_neighbors(int(probe))))
        np.testing.assert_array_equal(
            np.sort(db.in_neighbors(int(probe))),
            np.sort(db2.in_neighbors(int(probe))))

    # 5. restored store keeps serving writes
    db2.insert_edges(*stream.next_edges(5_000))
    assert db2.n_edges == db.n_edges + 5_000

    # 6. the same store powers device-side analytics (PSW both modes)
    dg = build_device_graph(db)
    r1 = pagerank_device(dg, n_iters=3, mode="dense_gather")
    r2 = pagerank_device(dg, n_iters=3, mode="psw_windows")
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                               rtol=1e-4, atol=1e-4)
