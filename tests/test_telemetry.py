"""Unified telemetry tests (ISSUE 9): registry primitives (per-thread
cells, labels, catalog enforcement), exact histogram merging, span
context propagation (nesting, attach, Chrome-trace export), the
ServiceDB integration (instrumented WAL/manifest/service paths, legacy
stats shims, metric-derived health), and a thread-safety regression for
snapshot-vs-writer races.

The registry is process-global, so every assertion on counters is a
DELTA between two snapshots — other tests in the same process may have
instrumented work of their own.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import ServiceDB, tail_cache_stats
from repro.core import telemetry
from repro.core.telemetry import (
    MetricsRegistry,
    merge_snapshots,
)


def _counter_total(snap, name):
    v = snap["counters"].get(name, 0)
    if isinstance(v, dict):
        return sum(v.values())
    return v


def make_service(tmp_path, name="db", **kw):
    opts = dict(max_id=9999, n_partitions=16, n_levels=3, branching=4,
                buffer_cap=2000, max_partition_edges=8000,
                persist_min_edges=512, wal_segment_bytes=64 << 10,
                checkpoint_interval_ops=10 ** 9)
    opts.update(kw)
    return ServiceDB.create(str(tmp_path / name), **opts)


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_counter_sums_across_threads(self):
        r = MetricsRegistry()
        c = r.counter("x.threads")
        n_threads, per = 8, 1000

        def worker():
            for _ in range(per):
                c.inc()

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert c.value() == n_threads * per
        assert r.snapshot()["counters"]["x.threads"] == n_threads * per

    def test_labeled_counter(self):
        r = MetricsRegistry()
        c = r.counter("x.labeled")
        c.inc(3, label="a")
        c.inc(label="b")
        c.inc(5)  # unlabeled remainder folds under ""
        assert c.value() == {"a": 3, "b": 1, "": 5}

    def test_catalog_enforced(self):
        r = MetricsRegistry()
        with pytest.raises(KeyError):
            r.counter("not.a.real.metric")  # lint: phantom-ok
        # a catalog name used with the wrong kind is a unit bug
        with pytest.raises(KeyError):
            r.counter("wal.append.seconds")
        # the escape prefix is caller-owned
        r.counter("x.anything.goes").inc()
        with pytest.raises(KeyError):
            with telemetry.span("not.a.span"):  # lint: phantom-ok
                pass

    def test_gauge_last_write_wins(self):
        r = MetricsRegistry()
        g = r.gauge("x.gauge")
        g.set(7)
        g.set(3)
        assert g.value() == 3

    def test_kill_switch(self):
        r = MetricsRegistry()
        c = r.counter("x.killed")
        telemetry.set_enabled(False)
        try:
            c.inc()
            with telemetry.span("x.killed.span") as sp:
                assert sp.trace is None  # the null handle
            assert c.value() == 0
        finally:
            telemetry.set_enabled(True)
        c.inc()
        assert c.value() == 1

    def test_register_stats_sums_live_instances(self):
        class Bag:
            def __init__(self, n):
                self.hits = n

        r = MetricsRegistry()
        a, b = Bag(3), Bag(4)
        r.register_stats(a, {"hits": "x.bag.hits"})
        r.register_stats(b, {"hits": "x.bag.hits"})
        assert r.snapshot()["counters"]["x.bag.hits"] == 7
        del b  # dead refs are pruned, their contribution disappears
        assert r.snapshot()["counters"]["x.bag.hits"] == 3


# ---------------------------------------------------------------------------
# histograms + exact merge
# ---------------------------------------------------------------------------
class TestHistogram:
    def test_count_sum_percentiles(self):
        r = MetricsRegistry()
        h = r.histogram("x.lat")
        for s in (0.001, 0.001, 0.002, 0.010):
            h.observe(s)
        v = h.value()[""]
        assert v["count"] == 4
        assert v["sum"] == pytest.approx(0.014)
        # p50 falls in the 1ms bucket; upper bounds are powers of two in us
        assert 1000 <= v["p50_us"] <= 2100
        assert v["p99_us"] >= v["p50_us"]

    def test_merge_is_exact(self):
        """merge_snapshots(two halves) == one registry seeing everything."""
        rng = np.random.default_rng(11)
        samples = rng.exponential(0.002, 400)
        r1, r2, ref = (MetricsRegistry() for _ in range(3))
        for i, s in enumerate(samples):
            (r1 if i % 2 else r2).histogram("x.lat").observe(s, label="l")
            ref.histogram("x.lat").observe(s, label="l")
        merged = merge_snapshots([r1.snapshot(), r2.snapshot()])
        got = merged["histograms"]["x.lat"]["l"]
        want = ref.snapshot()["histograms"]["x.lat"]["l"]
        assert got["buckets"] == want["buckets"]
        assert got["count"] == want["count"]
        assert got["sum"] == pytest.approx(want["sum"])
        assert got["p99_us"] == want["p99_us"]

    def test_merge_counters_scalar_and_labeled(self):
        a = {"pid": 1, "counters": {"x.c": 2, "x.d": {"k": 1}},
             "gauges": {}, "histograms": {}}
        b = {"pid": 2, "counters": {"x.c": 3, "x.d": 4},
             "gauges": {"x.g": 9}, "histograms": {}}
        m = merge_snapshots([a, b])
        assert m["counters"]["x.c"] == 5
        assert m["counters"]["x.d"] == {"k": 1, "": 4}
        assert m["gauges"]["x.g"] == 9
        assert m["pids"] == [1, 2]


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
class TestSpans:
    def test_nesting_shares_trace(self):
        with telemetry.span("x.outer") as outer:
            with telemetry.span("x.inner") as inner:
                assert inner.trace == outer.trace
                assert inner.parent == outer.span
        evs = telemetry.trace_events()
        by_name = {e["name"]: e for e in evs[-2:]}
        assert by_name["x.inner"]["args"]["parent"] == outer.span
        assert by_name["x.outer"]["args"]["trace"] == outer.trace

    def test_attach_joins_remote_trace(self):
        """The cross-process stitch, in miniature: a context exported on
        one thread re-establishes the same trace on another."""
        got = {}

        def remote(ctx):
            with telemetry.attach(ctx):
                with telemetry.span("x.remote") as sp:
                    got["trace"], got["parent"] = sp.trace, sp.parent

        with telemetry.span("x.root") as root:
            ctx = telemetry.current_context()
            t = threading.Thread(target=remote, args=(ctx,))
            t.start()
            t.join()
        assert got["trace"] == root.trace
        assert got["parent"] == root.span
        # attach(None) is a no-op, not an error
        with telemetry.attach(None):
            pass

    def test_chrome_trace_document(self, tmp_path):
        with telemetry.span("x.export", flavor="test") as sp:
            sp.tag(extra=1)
        out = tmp_path / "trace.json"
        doc = telemetry.trace_export(path=str(out))
        json.dumps(doc)  # loadable = serializable + right envelope
        assert doc["traceEvents"]
        ev = next(e for e in reversed(doc["traceEvents"])
                  if e["name"] == "x.export")
        assert ev["ph"] == "X" and ev["cat"] == "graphdb"
        for field in ("ts", "dur", "pid", "tid"):
            assert isinstance(ev[field], int)
        assert ev["args"]["flavor"] == "test"
        assert ev["args"]["extra"] == 1
        assert ev["args"]["trace"] == sp.trace
        assert json.loads(out.read_text())["displayTimeUnit"] == "ms"


# ---------------------------------------------------------------------------
# ServiceDB integration
# ---------------------------------------------------------------------------
class TestServiceIntegration:
    def test_instrumented_paths_record(self, tmp_path):
        before = telemetry.snapshot()
        svc = make_service(tmp_path)
        rng = np.random.default_rng(0)
        src = rng.integers(0, 10000, 6000)
        dst = rng.integers(0, 10000, 6000)
        svc.insert_edges(src, dst)
        svc.checkpoint()
        with svc.read_view() as view:
            view.storage_engine().out_neighbors_batch(
                np.unique(src[:256]))
        sess = svc.begin_snapshot()  # bumps the legacy ServiceStats bag
        sess.release()
        snap = svc.metrics_snapshot()
        for name in ("wal.appends", "wal.append.bytes",
                     "manifest.publishes", "disk.interval.read_edges"):
            assert (_counter_total(snap, name)
                    > _counter_total(before, name)), name
        # collector-backed legacy stats appear in the same snapshot
        assert (_counter_total(snap, "service.snapshots")
                >= svc.stats.snapshots > 0)
        hist = snap["histograms"]["wal.append.seconds"][""]
        assert hist["count"] > 0 and hist["sum"] > 0
        svc.close()

    def test_legacy_stats_shims_unchanged(self, tmp_path):
        """Satellite 1 back-compat: the dataclasses stay plain attribute
        bags — existing callers never see the registry."""
        svc = make_service(tmp_path)
        svc.insert_edges([1, 2, 3], [4, 5, 6])
        svc.checkpoint()
        assert isinstance(svc.stats.flushes, int)
        assert isinstance(svc.db.tree.stats.inserts, int)
        assert svc.db.tree.stats.inserts >= 3
        io = svc.db.io.snapshot()
        assert {"gathers", "block_reads", "bytes_read"} <= set(io)
        tc = tail_cache_stats()
        assert {"hits", "misses"} <= set(tc)
        svc.close()

    def test_prometheus_text(self, tmp_path):
        svc = make_service(tmp_path)
        svc.insert_edges([1], [2])
        text = svc.prometheus_text()
        assert "# TYPE graphdb_wal_appends counter" in text
        assert "graphdb_wal_append_seconds_bucket" in text
        assert 'le="+Inf"' in text
        svc.close()

    def test_health_readiness_fields(self, tmp_path):
        svc = make_service(tmp_path)
        svc.insert_edges([1, 2], [3, 4])
        h = svc.health()
        for key in ("wal_tail_budget_bytes", "wal_tail_ok", "backlog_ok",
                    "backlog_edges", "poisoned_count", "ready"):
            assert key in h, key
        assert h["wal_tail_bytes"] <= h["wal_tail_budget_bytes"]
        assert h["ready"] and h["wal_tail_ok"] and h["backlog_ok"]
        assert h["poisoned_count"] == 0
        # a tiny budget flips readiness without flipping liveness
        svc.wal_tail_budget_bytes = 1
        h2 = svc.health()
        assert not h2["wal_tail_ok"] and not h2["ready"]
        assert h2["maintenance_alive"]
        svc.close()

    def test_snapshot_thread_safe_under_load(self, tmp_path):
        """Regression: concurrent snapshot() readers against a writer and
        live maintenance must neither raise nor observe regressing
        counters (cells only grow; dict iteration must be race-free)."""
        svc = make_service(tmp_path)
        rng = np.random.default_rng(3)
        stop = threading.Event()
        errors = []

        def writer():
            try:
                while not stop.is_set():
                    svc.insert_edges(rng.integers(0, 10000, 500),
                                     rng.integers(0, 10000, 500))
            except Exception as exc:  # pragma: no cover
                errors.append(exc)

        def sampler():
            last = 0
            try:
                while not stop.is_set():
                    snap = telemetry.snapshot()
                    cur = _counter_total(snap, "wal.appends")
                    assert cur >= last, "counter went backwards"
                    last = cur
                    telemetry.prometheus_text()
            except Exception as exc:  # pragma: no cover
                errors.append(exc)

        threads = [threading.Thread(target=writer)] + \
            [threading.Thread(target=sampler) for _ in range(2)]
        for t in threads:
            t.start()
        import time
        time.sleep(1.0)
        stop.set()
        for t in threads:
            t.join()
        assert not errors
        svc.close()
