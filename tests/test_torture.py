"""ISSUE 7: the crash-consistency torture suite.

Three layers:

  * **Crash-point schedule** — arm one `<site>=crash` failpoint per run via
    the `GRAPHDB_FAILPOINTS` environment channel, run the deterministic
    torture workload (`repro.torture`) in a subprocess until it dies with
    `os._exit(41)` mid-I/O (or completes if the site is never crossed),
    then RECOVER IN A FRESH SUBPROCESS and assert the recovered store is
    bitwise-equal to a prefix of the op stream at least as long as the
    acked durable prefix — the same prefix-equality oracle PR 5 used for
    epochs, applied to crashes.
  * **Corruption** — flip bytes in partition files: lazy CRC verification
    must detect (typed `CorruptionError`, never garbage), quarantine must
    keep unaffected reads live, `wal_keep_history` must enable a full
    rebuild, and compacted-away history must be REPORTED unrecoverable.
  * **Degraded service** — injected ENOSPC sheds the `ServiceDB` to
    read-only (writes rejected typed, reads live) and auto-recovers when
    the condition clears.

Plus the ISSUE-7 satellites: dir-fsync-after-rename regression and the
degenerate recovery inputs (zero-length segment, truncated record, empty
manifest + live tail, snapshot dir missing a hard-linked segment).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import torture
from repro.core import (
    CRASH_EXIT_CODE,
    CorruptionError,
    FailpointError,
    GraphDB,
    ReadOnlyError,
    RecoveryError,
    ServiceDB,
    Snapshot,
    WALGapError,
    fp_clear,
    fp_hits,
    fp_set,
    fp_trace,
)
from repro.core.walog import SegmentedWAL

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def make_db(tmp_path, name="db", **kw):
    opts = dict(max_id=9999, n_partitions=16, n_levels=3, branching=4,
                buffer_cap=2000, max_partition_edges=8000,
                persist_min_edges=512)
    opts.update(kw)
    return GraphDB.create(str(tmp_path / name), **opts)


def coo_sorted(g):
    return sorted(zip(*map(list, g.to_coo())))


def _torture_subprocess(cmd, dbdir, oracle, failpoints=None,
                        batches=8, batch_size=120):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("GRAPHDB_FAILPOINTS", None)
    if failpoints:
        env["GRAPHDB_FAILPOINTS"] = failpoints
    return subprocess.run(
        [sys.executable, "-m", "repro.torture", cmd, dbdir,
         "--oracle", oracle, "--batches", str(batches),
         "--batch-size", str(batch_size)],
        env=env, capture_output=True, text=True, timeout=300)


# a bounded schedule for tier-1; benchmarks/bench_torture.py enumerates
# the whole registry (CI runs its --smoke subset)
CRASH_SCHEDULE = [
    "wal.append.write=crash@5",
    "wal.segment.create=crash@2",
    "part.write.rename=crash@1",
    "manifest.rename=crash@1",
    "wal.compact.unlink=crash",
    "service.flush.merge=crash@1",
    "service.ckpt.phaseB=crash",
    "dir.fsync=crash@4",
]


class TestChecksumPrimitives:
    def test_checksum32_detects_every_corruption_shape(self):
        from repro.core import checksum32
        rng = np.random.default_rng(3)
        buf = rng.integers(0, 255, 100_000, dtype=np.uint8).tobytes()
        c = checksum32(buf)
        assert checksum32(buf) == c  # deterministic
        for pos in (0, 1, 7, 8, 4095, 4096, len(buf) // 2, len(buf) - 1):
            b = bytearray(buf)
            b[pos] ^= 1
            assert checksum32(bytes(b)) != c, f"missed flip at {pos}"
        swapped = buf[4096:8192] + buf[:4096] + buf[8192:]
        assert checksum32(swapped) != c  # block reorder
        assert checksum32(buf[:-1]) != c  # truncation
        assert checksum32(buf + b"\0") != c  # zero extension
        assert checksum32(b"") == checksum32(b"")

    def test_checksum32_odd_lengths_and_array_inputs(self):
        from repro.core import checksum32
        rng = np.random.default_rng(4)
        raw = rng.integers(0, 255, 9000, dtype=np.uint8).tobytes()
        for n in (1, 7, 8, 9, 4095, 4096, 4097, 4104, 9000):
            x = raw[:n]
            v = checksum32(x)
            for pos in range(0, n, max(1, n // 7)):
                b = bytearray(x)
                b[pos] ^= 0x80
                assert checksum32(bytes(b)) != v, (n, pos)
        arr = np.frombuffer(raw[:8192], np.int64)
        assert checksum32(arr) == checksum32(raw[:8192])

    def test_record_checksum_length_dispatch(self):
        from repro.core import checksum32, crc32, record_checksum
        small = b"x" * 1023
        big = b"x" * 1024
        assert record_checksum(small) == crc32(small)
        assert record_checksum(big) == checksum32(big)


class TestCrashSchedule:
    @pytest.mark.parametrize("spec", CRASH_SCHEDULE)
    def test_crash_point_recovers_to_durable_prefix(self, tmp_path, spec):
        dbdir = str(tmp_path / "db")
        oracle = str(tmp_path / "oracle.log")
        run = _torture_subprocess("run", dbdir, oracle, failpoints=spec)
        assert run.returncode in (0, CRASH_EXIT_CODE), (
            f"{spec}: unexpected failure (rc={run.returncode}):\n"
            f"{run.stdout}\n{run.stderr}")
        ver = _torture_subprocess("verify", dbdir, oracle)
        assert ver.returncode == 0, (
            f"{spec}: recovery verification failed:\n{ver.stdout}\n"
            f"{ver.stderr}")

    def test_clean_run_recovers_everything(self, tmp_path):
        dbdir = str(tmp_path / "db")
        oracle = str(tmp_path / "oracle.log")
        assert _torture_subprocess("run", dbdir, oracle).returncode == 0
        res = torture.verify_recovery(dbdir, oracle, batches=8,
                                      batch_size=120)
        assert res["recovered_prefix"] == torture.total_ops(8)
        assert res["acked"] == res["recovered_prefix"]


class TestCorruption:
    def _build(self, tmp_path, n=4000, **kw):
        db = make_db(tmp_path, **kw)
        rng = np.random.default_rng(5)
        db.insert_edges(rng.integers(0, 10000, n),
                        rng.integers(0, 10000, n))
        db.checkpoint()
        coo = coo_sorted(db)
        manifest = db._read_manifest()
        db.tree.close()
        db.evict()
        digests = [e["digest"] for lv in manifest["levels"]
                   for e in lv if e]
        assert digests, "build must persist at least one partition"
        return db.dir, coo, digests

    @staticmethod
    def _flip_section_byte(path):
        """Flip one byte in the middle of the 'src' section body."""
        from repro.core.disk import _read_header
        hdr = _read_header(path)
        off, _, n = hdr["sections"]["src"]
        assert n > 0
        with open(path, "r+b") as f:
            f.seek(off + (n // 2) * 8)
            b = f.read(1)
            f.seek(off + (n // 2) * 8)
            f.write(bytes([b[0] ^ 0xFF]))

    def test_section_corruption_detected_never_served(self, tmp_path):
        dbdir, coo, digests = self._build(tmp_path)
        victim = digests[0]
        self._flip_section_byte(
            os.path.join(dbdir, "parts", f"part_{victim}.pal"))
        db = GraphDB.open(dbdir)  # header fine: corruption is lazy
        with pytest.raises(CorruptionError):
            db.to_coo()  # first touch of the rotted section
        db.tree.close()

    def test_quarantine_keeps_surviving_reads_live(self, tmp_path):
        dbdir, coo, digests = self._build(tmp_path)
        victim = digests[0]
        self._flip_section_byte(
            os.path.join(dbdir, "parts", f"part_{victim}.pal"))
        db = GraphDB.open(dbdir)
        with pytest.raises(CorruptionError):
            db.to_coo()
        assert db.quarantine(victim, detail="bit rot (test)")
        after = coo_sorted(db)  # unaffected partitions keep serving
        lost = db.integrity_report()["events"][0]["n_edges_lost"]
        assert lost > 0
        assert len(after) == len(coo) - lost
        remaining = set(map(tuple, coo))
        assert all(tuple(e) in remaining for e in after)
        assert victim in db.integrity_report()["quarantined"]
        assert os.path.exists(
            os.path.join(dbdir, "quarantine", f"part_{victim}.pal"))
        db.tree.close()

    def test_scrub_quarantines_bit_rot(self, tmp_path):
        dbdir, coo, digests = self._build(tmp_path)
        victim = digests[0]
        self._flip_section_byte(
            os.path.join(dbdir, "parts", f"part_{victim}.pal"))
        db = GraphDB.open(dbdir)
        report = db.scrub()
        assert report["quarantined"] == [victim]
        assert report["checked"] >= len(digests)
        coo_sorted(db)  # serves without raising
        db.tree.close()

    def test_corrupt_header_rebuilds_from_full_wal(self, tmp_path):
        dbdir, coo, digests = self._build(tmp_path, wal_keep_history=True)
        path = os.path.join(dbdir, "parts", f"part_{digests[0]}.pal")
        with open(path, "r+b") as f:
            f.seek(24)  # inside the JSON header: the header CRC catches it
            f.write(b"\xde\xad")
        db = GraphDB.open(dbdir)
        events = {e["event"] for e in db.integrity_log}
        assert "quarantine" in events and "rebuild" in events
        assert coo_sorted(db) == coo  # bitwise-equal full recovery
        # checkpoint re-derives a clean manifest; the next open is quiet
        db.checkpoint()
        db.tree.close()
        db2 = GraphDB.open(dbdir)
        assert db2.integrity_log == []
        assert coo_sorted(db2) == coo
        db2.tree.close()

    def test_compacted_history_reports_unrecoverable(self, tmp_path):
        dbdir, coo, digests = self._build(tmp_path)  # checkpoint compacted
        path = os.path.join(dbdir, "parts", f"part_{digests[0]}.pal")
        with open(path, "r+b") as f:
            f.seek(24)
            f.write(b"\xde\xad")
        db = GraphDB.open(dbdir)  # typed + reported, no unhandled raise
        rep = db.integrity_report()
        assert rep["unrecoverable"] and rep["unrecoverable"][0][
            "n_edges_lost"] > 0
        assert len(coo_sorted(db)) == len(coo) - sum(
            u["n_edges_lost"] for u in rep["unrecoverable"])
        db.tree.close()


class TestReadOnlyDegradation:
    def test_enospc_sheds_to_read_only_then_recovers(self, tmp_path):
        svc = ServiceDB.create(
            str(tmp_path / "db"), max_id=9999, n_partitions=16,
            n_levels=3, branching=4, buffer_cap=500,
            max_partition_edges=8000, persist_min_edges=256,
            checkpoint_interval_ops=300, max_job_failures=2,
            backoff_base_s=0.01, recovery_probe_s=0.05)
        rng = np.random.default_rng(11)
        try:
            svc.insert_edges(rng.integers(0, 10000, 200),
                             rng.integers(0, 10000, 200))
            fp_set("part.write.fsync", "errno:ENOSPC", count=None)
            deadline = _time() + 20.0
            saw_read_only = False
            while _time() < deadline:
                try:
                    svc.insert_edges(rng.integers(0, 10000, 100),
                                     rng.integers(0, 10000, 100))
                except ReadOnlyError:
                    saw_read_only = True
                    break
                _sleep(0.01)
            assert saw_read_only, "service never entered read-only"
            assert svc.read_only and svc.stats.read_only_entries >= 1
            # epoch reads stay live while degraded
            with svc.read_view() as view:
                assert view.n_edges > 0
            # the fault clears -> the recovery probe lifts read-only
            fp_clear()
            deadline = _time() + 20.0
            while svc.read_only and _time() < deadline:
                _sleep(0.02)
            assert not svc.read_only
            assert svc.stats.read_only_exits >= 1
            svc.insert_edges(rng.integers(0, 10000, 50),
                             rng.integers(0, 10000, 50))  # writes resumed
        finally:
            fp_clear()
            svc.maintenance_error = None
            svc.close()


class TestBackgroundScrub:
    def test_periodic_scrub_runs_and_counts(self, tmp_path):
        svc = ServiceDB.create(
            str(tmp_path / "db"), max_id=9999, n_partitions=16,
            n_levels=3, branching=4, buffer_cap=500,
            max_partition_edges=8000, persist_min_edges=256,
            checkpoint_interval_ops=10 ** 9, scrub_interval_s=0.1)
        rng = np.random.default_rng(9)
        try:
            svc.insert_edges(rng.integers(0, 10000, 2000),
                             rng.integers(0, 10000, 2000))
            svc.checkpoint()
            deadline = _time() + 20.0
            while svc.stats.scrubs == 0 and _time() < deadline:
                _sleep(0.02)
            assert svc.stats.scrubs >= 1, "background scrub never ran"
        finally:
            svc.close()

    def test_scrub_failure_never_degrades_writes(self, tmp_path):
        """A failing scrub is retried/poisoned but must NOT shed the
        service to read-only — it is a checker, not the persist path."""
        svc = ServiceDB.create(
            str(tmp_path / "db"), max_id=9999, n_partitions=16,
            n_levels=3, branching=4, buffer_cap=500,
            max_partition_edges=8000, persist_min_edges=256,
            checkpoint_interval_ops=10 ** 9, scrub_interval_s=0.05,
            max_job_failures=2, backoff_base_s=0.01)
        rng = np.random.default_rng(10)
        fp_set("service.scrub", "raise", count=None)
        try:
            svc.insert_edges(rng.integers(0, 10000, 1000),
                             rng.integers(0, 10000, 1000))
            deadline = _time() + 20.0
            while svc.stats.poisoned_jobs == 0 and _time() < deadline:
                _sleep(0.02)
            assert svc.stats.poisoned_jobs >= 1, "scrub never poisoned"
            assert not svc.read_only
            assert svc.maintenance_error is None
            svc.insert_edges(rng.integers(0, 10000, 100),
                             rng.integers(0, 10000, 100))  # writes fine
        finally:
            fp_clear()
            svc.close()


class TestDirFsyncSatellite:
    def test_every_atomic_publish_syncs_its_directory(self, tmp_path):
        fp_trace(True)
        try:
            db = make_db(tmp_path)
            rng = np.random.default_rng(2)
            db.insert_edges(rng.integers(0, 10000, 3000),
                            rng.integers(0, 10000, 3000))
            base = fp_hits("dir.fsync")
            db.checkpoint()  # manifest + parts dir + wal segment dirs
            after_ckpt = fp_hits("dir.fsync")
            assert after_ckpt > base
            db.pin_snapshot(str(tmp_path / "snap"))  # SNAPSHOT.json publish
            assert fp_hits("dir.fsync") > after_ckpt
            db.tree.close()
        finally:
            fp_trace(False)

    def test_dir_fsync_is_on_the_publish_path(self, tmp_path):
        """Failpoint-driven: failing the directory fsync fails the
        checkpoint — proof the sync actually guards the rename."""
        db = make_db(tmp_path)
        rng = np.random.default_rng(3)
        db.insert_edges(rng.integers(0, 10000, 2000),
                        rng.integers(0, 10000, 2000))
        fp_set("dir.fsync", "raise", count=1)
        try:
            with pytest.raises(FailpointError):
                db.checkpoint()
        finally:
            fp_clear()
        db.checkpoint()  # cleared: publishes fine
        # same for the snapshot publish rename
        fp_set("snapshot.json.rename", "raise", count=1)
        try:
            with pytest.raises(FailpointError):
                db.pin_snapshot(str(tmp_path / "snap_fail"))
        finally:
            fp_clear()
        db.pin_snapshot(str(tmp_path / "snap_ok"))
        assert Snapshot.open(str(tmp_path / "snap_ok")).n_edges > 0
        db.tree.close()


class TestDegenerateRecoveryInputs:
    def test_zero_length_tail_segment_skipped(self, tmp_path):
        w = SegmentedWAL(str(tmp_path / "wal"), column_dtypes={})
        w.append_inserts([1, 2], [3, 4], [0, 0], {})
        w.flush(fsync=True)
        end = w.tail_offset()
        w.close()
        # a crash at segment-create time leaves a zero-length file
        open(os.path.join(str(tmp_path / "wal"),
                          f"seg_{end:020d}.wal"), "wb").close()
        w2 = SegmentedWAL(str(tmp_path / "wal"), column_dtypes={})
        ops = list(w2.replay())
        assert len(ops) == 1 and ops[0][0] == "insert"
        w2.close()

    def test_zero_length_mid_chain_segment_is_typed_gap(self, tmp_path):
        w = SegmentedWAL(str(tmp_path / "wal"), column_dtypes={},
                         segment_bytes=64)  # tiny: every append rotates
        for i in range(6):
            w.append_inserts([i], [i + 1], [0], {})
        w.flush(fsync=True)
        w.close()
        segs = sorted(f for f in os.listdir(str(tmp_path / "wal"))
                      if f.endswith(".wal"))
        assert len(segs) >= 3
        mid = os.path.join(str(tmp_path / "wal"), segs[1])
        open(mid, "wb").close()  # truncate an INTERIOR segment to zero
        w2 = SegmentedWAL(str(tmp_path / "wal"), column_dtypes={})
        with pytest.raises(WALGapError):
            list(w2.replay())
        w2.close()

    def test_truncated_final_record_recovers_prefix(self, tmp_path):
        w = SegmentedWAL(str(tmp_path / "wal"), column_dtypes={})
        w.append_inserts([1], [2], [0], {})
        w.append_inserts([3], [4], [0], {})
        w.flush(fsync=True)
        w.close()
        segs = sorted(f for f in os.listdir(str(tmp_path / "wal"))
                      if f.endswith(".wal"))
        path = os.path.join(str(tmp_path / "wal"), segs[-1])
        with open(path, "r+b") as f:
            f.seek(0, os.SEEK_END)
            f.truncate(f.tell() - 5)  # torn mid-record, crosses the CRC
        w2 = SegmentedWAL(str(tmp_path / "wal"), column_dtypes={})
        ops = list(w2.replay())
        assert len(ops) == 1  # the durable prefix, not garbage
        w2.close()

    def test_empty_manifest_with_live_wal_tail(self, tmp_path):
        db = make_db(tmp_path)  # create wrote an all-empty manifest
        rng = np.random.default_rng(4)
        db.insert_edges(rng.integers(0, 10000, 500),
                        rng.integers(0, 10000, 500))
        coo = coo_sorted(db)
        db.tree.wal_flush(fsync=True)
        db.tree.close()  # NO checkpoint: state lives only in the WAL
        db2 = GraphDB.open(db.dir)
        assert coo_sorted(db2) == coo
        db2.tree.close()

    def test_snapshot_missing_hard_linked_segment_is_typed(self, tmp_path):
        db = make_db(tmp_path)
        rng = np.random.default_rng(6)
        db.insert_edges(rng.integers(0, 10000, 3000),
                        rng.integers(0, 10000, 3000))
        db.checkpoint()
        db.insert_edges(rng.integers(0, 10000, 200),
                        rng.integers(0, 10000, 200))  # live tail
        dest = str(tmp_path / "snap")
        db.pin_snapshot(dest)
        segs = sorted(f for f in os.listdir(os.path.join(dest, "wal"))
                      if f.endswith(".wal"))
        assert segs, "pin must hard-link the tail segment"
        os.remove(os.path.join(dest, "wal", segs[0]))
        with pytest.raises(RecoveryError):
            Snapshot.open(dest)
        db.tree.close()


def _time():
    import time
    return time.monotonic()


def _sleep(s):
    import time
    time.sleep(s)
