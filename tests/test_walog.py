"""Segmented WAL unit tests: record roundtrips, rotation, torn tails,
compaction, replay windows (ISSUE 4)."""
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.walog import SegmentedWAL


def wal_at(tmp_path, name="wal", **kw):
    opts = dict(column_dtypes={"w": np.float32}, segment_bytes=512)
    opts.update(kw)
    return SegmentedWAL(str(tmp_path / name), **opts)


class TestRecords:
    def test_insert_roundtrip_with_columns(self, tmp_path):
        w = wal_at(tmp_path)
        w.append_inserts([1, 2], [3, 4], [0, 1], {"w": [1.5, 2.5]})
        ((kind, s, d, t, cols),) = list(w.replay())
        assert kind == "insert"
        assert s.tolist() == [1, 2] and d.tolist() == [3, 4]
        assert t.tolist() == [0, 1]
        np.testing.assert_allclose(cols["w"], [1.5, 2.5])

    def test_missing_column_logs_zeros(self, tmp_path):
        w = wal_at(tmp_path)
        w.append_inserts([7], [8], [0], {})
        ((_, _, _, _, cols),) = list(w.replay())
        assert cols["w"][0] == 0.0

    def test_delete_and_column_records(self, tmp_path):
        w = wal_at(tmp_path)
        w.append_inserts([1], [2], [0], {})
        w.append_delete(1, 2)
        w.append_column("w", 1, 2, 9.25)
        ops = list(w.replay())
        assert ops[1] == ("delete", 1, 2)
        kind, name, s, d, val = ops[2]
        assert (kind, name, s, d) == ("column", "w", 1, 2)
        assert val == np.float32(9.25)

    def test_schema_mismatch_rejected(self, tmp_path):
        w = wal_at(tmp_path)
        w.append_delete(1, 2)
        w.close()
        with pytest.raises(AssertionError):
            wal_at(tmp_path, column_dtypes={"other": np.int64})

    def test_empty_insert_writes_nothing(self, tmp_path):
        w = wal_at(tmp_path)
        w.append_inserts([], [], [], {})
        assert w.tail_offset() == 0


class TestSegments:
    def test_rotation_and_offsets_survive(self, tmp_path):
        w = wal_at(tmp_path, segment_bytes=256)
        for i in range(20):
            w.append_inserts(np.arange(10) + i, np.arange(10), np.zeros(10, np.int8),
                             {"w": np.full(10, float(i))})
        segs = w.segments()
        assert len(segs) > 1, "no rotation happened"
        # bases are contiguous: each segment starts where the last ended
        for (b0, e0, _), (b1, _, _) in zip(segs, segs[1:]):
            assert e0 == b1
        ops = list(w.replay())
        assert len(ops) == 20
        assert ops[13][4]["w"][0] == 13.0

    def test_compaction_deletes_covered_segments_only(self, tmp_path):
        w = wal_at(tmp_path, segment_bytes=256)
        marks = []
        for i in range(20):
            w.append_inserts([i], [i + 1], [0], {})
            marks.append(w.tail_offset())
        before = w.on_disk_bytes()
        covered = marks[9]
        removed = w.compact(covered)
        assert removed >= 1
        assert w.on_disk_bytes() < before
        # everything at/after the covered offset still replays
        tail = list(w.replay(offset=covered))
        assert [int(op[1][0]) for op in tail] == list(range(10, 20))

    def test_compact_rotates_fully_covered_active_segment(self, tmp_path):
        w = wal_at(tmp_path, segment_bytes=1 << 20)  # never auto-rotates
        w.append_inserts([1], [2], [0], {})
        tail = w.tail_offset()
        w.compact(tail)  # active segment fully covered: rotated + deleted
        assert list(w.replay()) == []
        w.append_inserts([3], [4], [0], {})
        assert [int(op[1][0]) for op in w.replay()] == [3]

    def test_replay_window(self, tmp_path):
        w = wal_at(tmp_path)
        w.append_inserts([1], [2], [0], {})
        a = w.tail_offset()
        w.append_delete(5, 6)
        b = w.tail_offset()
        w.append_inserts([7], [8], [0], {})
        assert list(w.replay(offset=a, end=b)) == [("delete", 5, 6)]


class TestCrash:
    def test_torn_tail_dropped_and_truncated_on_reopen(self, tmp_path):
        w = wal_at(tmp_path)
        w.append_inserts([1], [2], [0], {})
        good = w.tail_offset()
        w.flush()
        seg = w.segments()[-1][2]
        with open(seg, "ab") as f:
            f.write(b"\x01\x05\x00")  # torn INSERT header
        assert len(list(w.replay())) == 1  # reader drops the torn record
        w2 = wal_at(tmp_path)  # writer truncates back to the boundary
        assert w2.tail_offset() == good
        w2.append_delete(9, 9)
        assert list(w2.replay())[-1] == ("delete", 9, 9)

    def test_torn_header_tail_segment_quarantined(self, tmp_path):
        """A crash during rotation can leave the newest segment file with
        no (or a partial) header; it holds no acked records, so reopen
        deletes it and replay/readonly skip it instead of raising."""
        w = wal_at(tmp_path)
        w.append_inserts([1], [2], [0], {})
        tail = w.tail_offset()
        w.close()
        wal_dir = str(tmp_path / "wal")
        open(os.path.join(wal_dir, f"seg_{tail:020d}.wal"), "wb").close()
        with open(os.path.join(wal_dir, f"seg_{tail + 1:020d}.wal"),
                  "wb") as f:
            f.write(b"GCDBWAL1\x40")  # magic + partial header length
        r = SegmentedWAL(wal_dir, readonly=True)
        assert len(list(r.replay())) == 1 and r.tail_offset() == tail
        w2 = wal_at(tmp_path)  # writer quarantines the torn files
        assert w2.tail_offset() == tail
        w2.append_delete(5, 6)
        assert list(w2.replay())[-1] == ("delete", 5, 6)

    def test_readonly_never_compacts(self, tmp_path):
        w = wal_at(tmp_path)
        w.append_inserts([1], [2], [0], {})
        w.close()
        r = SegmentedWAL(str(tmp_path / "wal"), readonly=True)
        assert r.compact(10 ** 9) == 0
        assert len(list(r.replay())) == 1


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 60))
@settings(max_examples=15, deadline=None)
def test_property_replay_equals_append_order(seed, n_ops):
    """Arbitrary op sequences with small rotation thresholds replay back in
    order with identical payloads, across a close/reopen."""
    import tempfile
    rng = np.random.default_rng(seed)
    with tempfile.TemporaryDirectory() as d:
        w = SegmentedWAL(os.path.join(d, "wal"),
                         column_dtypes={"x": np.int32},
                         segment_bytes=int(rng.integers(64, 512)))
        expect = []
        for _ in range(n_ops):
            k = int(rng.integers(0, 3))
            if k == 0:
                n = int(rng.integers(1, 5))
                s = rng.integers(0, 100, n)
                t = rng.integers(0, 100, n)
                x = rng.integers(0, 100, n).astype(np.int32)
                w.append_inserts(s, t, np.zeros(n, np.int8), {"x": x})
                expect.append(("insert", s.tolist(), t.tolist(), x.tolist()))
            elif k == 1:
                s, t = int(rng.integers(0, 100)), int(rng.integers(0, 100))
                w.append_delete(s, t)
                expect.append(("delete", s, t))
            else:
                s, t = int(rng.integers(0, 100)), int(rng.integers(0, 100))
                v = int(rng.integers(0, 100))
                w.append_column("x", s, t, v)
                expect.append(("column", s, t, v))
        w.close()
        r = SegmentedWAL(os.path.join(d, "wal"), readonly=True)
        got = []
        for op in r.replay():
            if op[0] == "insert":
                got.append(("insert", op[1].tolist(), op[2].tolist(),
                            op[4]["x"].tolist()))
            elif op[0] == "delete":
                got.append(("delete", op[1], op[2]))
            else:
                got.append(("column", op[2], op[3], int(op[4])))
        assert got == expect
